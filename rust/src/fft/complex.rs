//! Minimal complex number type (no `num-complex` in the vendored registry).

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// A double-precision complex number.
///
/// `#[repr(C)]` so a `&[Complex64]` can be reinterpreted as interleaved
/// `&[f64]` of twice the length when crossing the PJRT boundary.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
#[repr(C)]
pub struct Complex64 {
    /// Real part.
    pub re: f64,
    /// Imaginary part.
    pub im: f64,
}

// SAFETY: two f64s, `repr(C)`, no drop glue, any bit pattern valid.
unsafe impl crate::util::Pod for Complex64 {}

/// 0 + 0i.
pub const ZERO: Complex64 = Complex64 { re: 0.0, im: 0.0 };
/// 1 + 0i.
pub const ONE: Complex64 = Complex64 { re: 1.0, im: 0.0 };
/// 0 + 1i.
pub const I: Complex64 = Complex64 { re: 0.0, im: 1.0 };

impl Complex64 {
    /// Complex number from parts.
    #[inline]
    pub const fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    /// 0 + 0i.
    #[inline]
    pub const fn zero() -> Self {
        ZERO
    }

    /// 1 + 0i.
    #[inline]
    pub const fn one() -> Self {
        ONE
    }

    /// `e^{iθ} = cos θ + i sin θ`.
    #[inline]
    pub fn cis(theta: f64) -> Self {
        let (s, c) = theta.sin_cos();
        Self { re: c, im: s }
    }

    /// Complex conjugate.
    #[inline]
    pub fn conj(self) -> Self {
        Self {
            re: self.re,
            im: -self.im,
        }
    }

    /// Squared modulus |z|².
    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// Modulus |z|.
    #[inline]
    pub fn abs(self) -> f64 {
        self.norm_sqr().sqrt()
    }

    /// Argument in (-π, π].
    #[inline]
    pub fn arg(self) -> f64 {
        self.im.atan2(self.re)
    }

    /// Multiply by a real scalar.
    #[inline]
    pub fn scale(self, s: f64) -> Self {
        Self {
            re: self.re * s,
            im: self.im * s,
        }
    }

    /// Fused multiply-add: `self + a * b` (complex).
    #[inline]
    pub fn mul_add(self, a: Complex64, b: Complex64) -> Self {
        Self {
            re: self.re + a.re * b.re - a.im * b.im,
            im: self.im + a.re * b.im + a.im * b.re,
        }
    }

    /// Multiplication by ±i without a full complex multiply.
    #[inline]
    pub fn mul_i(self) -> Self {
        Self {
            re: -self.im,
            im: self.re,
        }
    }

    /// Multiplication by −i without a full complex multiply.
    #[inline]
    pub fn mul_neg_i(self) -> Self {
        Self {
            re: self.im,
            im: -self.re,
        }
    }

    /// True when both parts are finite.
    #[inline]
    pub fn is_finite(self) -> bool {
        self.re.is_finite() && self.im.is_finite()
    }
}

impl Add for Complex64 {
    type Output = Self;
    #[inline]
    fn add(self, o: Self) -> Self {
        Self::new(self.re + o.re, self.im + o.im)
    }
}

impl Sub for Complex64 {
    type Output = Self;
    #[inline]
    fn sub(self, o: Self) -> Self {
        Self::new(self.re - o.re, self.im - o.im)
    }
}

impl Mul for Complex64 {
    type Output = Self;
    #[inline]
    fn mul(self, o: Self) -> Self {
        Self::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl Mul<f64> for Complex64 {
    type Output = Self;
    #[inline]
    fn mul(self, s: f64) -> Self {
        self.scale(s)
    }
}

impl Div for Complex64 {
    type Output = Self;
    #[inline]
    fn div(self, o: Self) -> Self {
        let d = o.norm_sqr();
        Self::new(
            (self.re * o.re + self.im * o.im) / d,
            (self.im * o.re - self.re * o.im) / d,
        )
    }
}

impl Div<f64> for Complex64 {
    type Output = Self;
    #[inline]
    fn div(self, s: f64) -> Self {
        Self::new(self.re / s, self.im / s)
    }
}

impl Neg for Complex64 {
    type Output = Self;
    #[inline]
    fn neg(self) -> Self {
        Self::new(-self.re, -self.im)
    }
}

impl AddAssign for Complex64 {
    #[inline]
    fn add_assign(&mut self, o: Self) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl SubAssign for Complex64 {
    #[inline]
    fn sub_assign(&mut self, o: Self) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl MulAssign for Complex64 {
    #[inline]
    fn mul_assign(&mut self, o: Self) {
        *self = *self * o;
    }
}

impl Sum for Complex64 {
    fn sum<I: Iterator<Item = Self>>(iter: I) -> Self {
        iter.fold(ZERO, |a, b| a + b)
    }
}

impl From<f64> for Complex64 {
    #[inline]
    fn from(re: f64) -> Self {
        Self::new(re, 0.0)
    }
}

impl fmt::Display for Complex64 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.im >= 0.0 {
            write!(f, "{}+{}i", self.re, self.im)
        } else {
            write!(f, "{}{}i", self.re, self.im)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: Complex64, b: Complex64) -> bool {
        (a - b).abs() < 1e-12
    }

    #[test]
    fn field_ops() {
        let a = Complex64::new(1.0, 2.0);
        let b = Complex64::new(-3.0, 0.5);
        assert!(close(a + b, Complex64::new(-2.0, 2.5)));
        assert!(close(a - b, Complex64::new(4.0, 1.5)));
        assert!(close(a * b, Complex64::new(-3.0 - 1.0, 0.5 - 6.0)));
        assert!(close((a / b) * b, a));
        assert!(close(-a + a, ZERO));
    }

    #[test]
    fn cis_and_conj() {
        let z = Complex64::cis(0.3);
        assert!((z.abs() - 1.0).abs() < 1e-15);
        assert!(close(z * z.conj(), ONE));
        assert!((z.arg() - 0.3).abs() < 1e-15);
    }

    #[test]
    fn mul_i_matches_full_multiply() {
        let z = Complex64::new(0.7, -1.3);
        assert!(close(z.mul_i(), z * I));
        assert!(close(z.mul_neg_i(), z * -I));
    }

    #[test]
    fn mul_add_matches_separate_ops() {
        let acc = Complex64::new(0.1, 0.2);
        let a = Complex64::new(1.5, -0.5);
        let b = Complex64::new(-2.0, 3.0);
        assert!(close(acc.mul_add(a, b), acc + a * b));
    }

    #[test]
    fn repr_c_interleave() {
        let v = [Complex64::new(1.0, 2.0), Complex64::new(3.0, 4.0)];
        // SAFETY: Complex64 is repr(C) { re: f64, im: f64 }, so two of
        // them are exactly four contiguous f64s; `v` outlives the view.
        let flat: &[f64] =
            unsafe { std::slice::from_raw_parts(v.as_ptr() as *const f64, 4) };
        assert_eq!(flat, &[1.0, 2.0, 3.0, 4.0]);
    }
}

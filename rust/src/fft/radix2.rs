//! Iterative in-place radix-2 Cooley–Tukey FFT for power-of-two sizes.
//!
//! This is the hot 1-D kernel of the 2-D FFT stage: for a bandwidth-B
//! transform it runs 2B·2B times per β-slice, so it is written to be
//! allocation-free given a prepared [`Radix2Plan`] (twiddles and the
//! bit-reversal permutation are precomputed once per size).

use super::{Complex64, Sign};

/// Precomputed tables for a radix-2 transform of size `n` (power of two).
#[derive(Debug, Clone)]
pub struct Radix2Plan {
    n: usize,
    /// Bit-reversal permutation; `bitrev[i]` is `i` with log2(n) bits reversed.
    bitrev: Vec<u32>,
    /// Twiddles for the negative-sign transform, packed per stage:
    /// stage with half-size `h` contributes `h` entries `e^{-πi k/h}`,
    /// k = 0..h. Total n-1 entries.
    twiddles_neg: Vec<Complex64>,
}

impl Radix2Plan {
    /// Build a plan; panics if `n` is not a power of two (callers dispatch
    /// through [`super::plan::FftPlan`] which guards this).
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two(), "radix-2 plan requires power-of-two n");
        let bits = n.trailing_zeros();
        let bitrev = (0..n as u32)
            .map(|i| {
                if bits == 0 {
                    0
                } else {
                    i.reverse_bits() >> (32 - bits)
                }
            })
            .collect();
        let mut twiddles_neg = Vec::with_capacity(n.saturating_sub(1));
        let mut h = 1;
        while h < n {
            let base = -std::f64::consts::PI / h as f64;
            for k in 0..h {
                twiddles_neg.push(Complex64::cis(base * k as f64));
            }
            h *= 2;
        }
        Self {
            n,
            bitrev,
            twiddles_neg,
        }
    }

    /// Transform size n.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// Whether the transform size is zero.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// In-place transform, unnormalized.
    pub fn process(&self, data: &mut [Complex64], sign: Sign) {
        assert_eq!(data.len(), self.n, "radix-2 plan size mismatch");
        let n = self.n;
        if n <= 1 {
            return;
        }
        // Bit-reversal permutation (swap once per pair).
        for i in 0..n {
            let j = self.bitrev[i] as usize;
            if i < j {
                data.swap(i, j);
            }
        }
        // Butterfly stages. Twiddles are stored for the negative sign;
        // conjugate on the fly for the positive sign (branch hoisted out
        // of the inner loop by monomorphizing on `flip`).
        match sign {
            Sign::Negative => self.stages::<false>(data),
            Sign::Positive => self.stages::<true>(data),
        }
    }

    #[inline]
    fn stages<const CONJ: bool>(&self, data: &mut [Complex64]) {
        let n = self.n;
        let mut h = 1;
        let mut toff = 0; // offset into the packed twiddle table
        // lint: hot-loop-begin
        while h < n {
            let step = 2 * h;
            let tw = &self.twiddles_neg[toff..toff + h];
            // Split each block into (lo, hi) halves so the inner loop is
            // three bounds-check-free zipped streams the vectorizer likes.
            for block in data.chunks_exact_mut(step) {
                let (lo, hi) = block.split_at_mut(h);
                for ((a, b), w) in lo.iter_mut().zip(hi.iter_mut()).zip(tw) {
                    let w = if CONJ { w.conj() } else { *w };
                    let t = *b * w;
                    let u = *a;
                    *a = u + t;
                    *b = u - t;
                }
            }
            toff += h;
            h = step;
        }
        // lint: hot-loop-end
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::dft;
    use crate::prng::Xoshiro256;

    fn random_signal(n: usize, seed: u64) -> Vec<Complex64> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n)
            .map(|_| Complex64::new(rng.next_signed(), rng.next_signed()))
            .collect()
    }

    #[test]
    fn matches_oracle_all_pow2_sizes() {
        for log in 0..=10 {
            let n = 1usize << log;
            let plan = Radix2Plan::new(n);
            for sign in [Sign::Negative, Sign::Positive] {
                let x = random_signal(n, 100 + log as u64);
                let want = dft(&x, sign);
                let mut got = x.clone();
                plan.process(&mut got, sign);
                for (a, b) in want.iter().zip(got.iter()) {
                    assert!(
                        (*a - *b).abs() < 1e-8 * (n as f64),
                        "n={n} sign={sign:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn roundtrip_scales_by_n() {
        let n = 256;
        let plan = Radix2Plan::new(n);
        let x = random_signal(n, 7);
        let mut y = x.clone();
        plan.process(&mut y, Sign::Negative);
        plan.process(&mut y, Sign::Positive);
        for (a, b) in x.iter().zip(y.iter()) {
            assert!((a.scale(n as f64) - *b).abs() < 1e-9 * n as f64);
        }
    }

    #[test]
    fn linearity() {
        let n = 64;
        let plan = Radix2Plan::new(n);
        let x = random_signal(n, 1);
        let y = random_signal(n, 2);
        let sum: Vec<Complex64> = x.iter().zip(&y).map(|(a, b)| *a + *b).collect();
        let mut fx = x.clone();
        let mut fy = y.clone();
        let mut fs = sum.clone();
        plan.process(&mut fx, Sign::Negative);
        plan.process(&mut fy, Sign::Negative);
        plan.process(&mut fs, Sign::Negative);
        for i in 0..n {
            assert!((fx[i] + fy[i] - fs[i]).abs() < 1e-10);
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn rejects_non_pow2() {
        let _ = Radix2Plan::new(12);
    }
}

//! Split-radix-family (radix-4) Cooley–Tukey FFT for power-of-two sizes.
//!
//! The 1-D workhorse of the overhauled FFT stage. Compared to the radix-2
//! kernel it halves the number of butterfly passes over the data
//! (log₄ n stages instead of log₂ n) and performs 3 complex multiplies
//! per 4 outputs instead of 4 — the same multiply-count class as true
//! split-radix, with a dramatically simpler (and therefore
//! vectorizer-friendlier) control structure. The decomposition:
//!
//! * bit-reversal permutation (plain radix-2 reversal);
//! * one radix-2 head stage when log₂ n is odd;
//! * radix-4 DIT stages. After radix-2 bit reversal the four sub-blocks
//!   of each group hold the sub-DFTs of the residue classes in the order
//!   `[0, 2, 1, 3]` (the 2-bit-reversed residues), so the butterfly reads
//!   `E0, E2, E1, E3` from consecutive blocks — no base-4 digit-reversal
//!   pass is needed.
//!
//! Two entry points share the tables: [`Radix4Plan::process`] for
//! contiguous (stride-1) signals — the row pass of the 2-D transform —
//! and [`Radix4Plan::process_panel`] for *strided column panels*: up to
//! four adjacent columns of a row-major matrix transformed in place,
//! with the butterflies running directly over the strided layout. A
//! 4-column panel of 16-byte complex values is exactly one 64-byte cache
//! line per row, so the panel pass touches every line of the matrix once
//! per *transform* (the panel stays cache-resident across stages) instead
//! of three times per gather→FFT→scatter sweep.

use super::{Complex64, Sign};
use crate::simd::SimdIsa;

/// Precomputed tables for a radix-4 transform of size `n` (power of two).
#[derive(Debug, Clone)]
pub struct Radix4Plan {
    n: usize,
    /// Bit-reversal permutation; `bitrev[i]` is `i` with log2(n) bits reversed.
    bitrev: Vec<u32>,
    /// Twiddles for the negative-sign transform, packed per radix-4 stage:
    /// the stage with quarter-size `h` contributes `h` triples
    /// `(ω^k, ω^{2k}, ω^{3k})` with `ω = e^{-2πi/(4h)}`, k = 0..h.
    twiddles_neg: Vec<Complex64>,
    /// Resolved instruction set the butterfly stages run with; decided
    /// at plan build (never probed per transform).
    isa: SimdIsa,
}

impl Radix4Plan {
    /// Build a plan with the process-detected ISA; panics if `n` is not
    /// a power of two (callers dispatch through [`super::plan::FftPlan`]
    /// which guards this).
    pub fn new(n: usize) -> Self {
        Self::with_isa(n, crate::simd::detected_isa())
    }

    /// Build a plan pinned to a specific butterfly ISA (the executor
    /// passes the plan-resolved policy; `new` uses auto-detection).
    /// Panics if `n` is not a power of two, or if `isa` names a vector
    /// extension the host does not support — the latter keeps the
    /// `unsafe` kernel calls sound by construction.
    pub fn with_isa(n: usize, isa: SimdIsa) -> Self {
        assert!(n.is_power_of_two(), "radix-4 plan requires power-of-two n");
        assert!(
            match isa {
                SimdIsa::Scalar => true,
                SimdIsa::Avx2 => crate::simd::avx2_supported(),
                SimdIsa::Neon => crate::simd::neon_supported(),
            },
            "radix-4 plan: ISA {} not supported on this host",
            isa.name()
        );
        let bits = n.trailing_zeros();
        let bitrev = (0..n as u32)
            .map(|i| {
                if bits == 0 {
                    0
                } else {
                    i.reverse_bits() >> (32 - bits)
                }
            })
            .collect();
        let mut twiddles_neg = Vec::new();
        let mut h = if bits % 2 == 1 { 2 } else { 1 };
        while h < n {
            let step = 4 * h;
            let base = -std::f64::consts::TAU / step as f64;
            for k in 0..h {
                twiddles_neg.push(Complex64::cis(base * k as f64));
                twiddles_neg.push(Complex64::cis(base * (2 * k) as f64));
                twiddles_neg.push(Complex64::cis(base * (3 * k) as f64));
            }
            h = step;
        }
        Self {
            n,
            bitrev,
            twiddles_neg,
            isa,
        }
    }

    /// Transform size n.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// The butterfly ISA this plan was built with.
    #[inline]
    pub fn isa(&self) -> SimdIsa {
        self.isa
    }

    /// Whether the transform size is zero.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// In-place transform of a contiguous signal, unnormalized.
    pub fn process(&self, data: &mut [Complex64], sign: Sign) {
        assert_eq!(data.len(), self.n, "radix-4 plan size mismatch");
        let n = self.n;
        if n <= 1 {
            return;
        }
        for i in 0..n {
            let j = self.bitrev[i] as usize;
            if i < j {
                data.swap(i, j);
            }
        }
        match (sign, self.isa) {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `with_isa` asserted AVX2+FMA support for this ISA.
            (_, SimdIsa::Avx2) => unsafe {
                super::simd::avx2::stages(data, &self.twiddles_neg, matches!(sign, Sign::Positive))
            },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is baseline on aarch64.
            (_, SimdIsa::Neon) => unsafe {
                super::simd::neon::stages(data, &self.twiddles_neg, matches!(sign, Sign::Positive))
            },
            (Sign::Negative, _) => self.stages::<false>(data),
            (Sign::Positive, _) => self.stages::<true>(data),
        }
    }

    /// In-place transform of a *panel* of `cols` adjacent columns of a
    /// row-major matrix: element `r` of column `c` lives at
    /// `data[r * stride + c]`. The butterflies run directly over the
    /// strided layout — no gather/scatter copies. `cols` must be in
    /// `1..=stride` and `data` must cover the last row
    /// (`(n-1) * stride + cols` elements).
    pub fn process_panel(
        &self,
        data: &mut [Complex64],
        stride: usize,
        cols: usize,
        sign: Sign,
    ) {
        let n = self.n;
        assert!(cols >= 1 && cols <= stride, "panel: 1 <= cols <= stride");
        assert!(
            data.len() >= (n - 1) * stride + cols,
            "panel: data too short for {n} rows at stride {stride}"
        );
        if n <= 1 {
            return;
        }
        for i in 0..n {
            let j = self.bitrev[i] as usize;
            if i < j {
                let (ri, rj) = (i * stride, j * stride);
                for c in 0..cols {
                    data.swap(ri + c, rj + c);
                }
            }
        }
        match (sign, self.isa) {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `with_isa` asserted AVX2+FMA support; `cols == 4`
            // matches the kernel's fixed panel width. Narrower panels
            // fall through to the scalar stages (which also preserves
            // the untouched-column bit-identity contract).
            (_, SimdIsa::Avx2) if cols == 4 => unsafe {
                super::simd::avx2::stages_panel4(
                    data,
                    n,
                    stride,
                    &self.twiddles_neg,
                    matches!(sign, Sign::Positive),
                )
            },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is baseline on aarch64.
            (_, SimdIsa::Neon) => unsafe {
                super::simd::neon::stages_panel(
                    data,
                    n,
                    stride,
                    cols,
                    &self.twiddles_neg,
                    matches!(sign, Sign::Positive),
                )
            },
            (Sign::Negative, _) => self.stages_panel::<false>(data, stride, cols),
            (Sign::Positive, _) => self.stages_panel::<true>(data, stride, cols),
        }
    }

    /// Contiguous butterfly stages. Twiddles are stored for the negative
    /// sign; conjugated on the fly for the positive sign (branch hoisted
    /// out of the inner loop by monomorphizing on `CONJ`).
    #[inline]
    fn stages<const CONJ: bool>(&self, data: &mut [Complex64]) {
        let n = self.n;
        let mut h = 1usize;
        if n.trailing_zeros() % 2 == 1 {
            // Radix-2 head stage (twiddle-free: ω⁰ = 1).
            for pair in data.chunks_exact_mut(2) {
                let a = pair[0];
                let b = pair[1];
                pair[0] = a + b;
                pair[1] = a - b;
            }
            h = 2;
        }
        let mut toff = 0; // offset into the packed twiddle-triple table
        // lint: hot-loop-begin
        while h < n {
            let step = 4 * h;
            let tw = &self.twiddles_neg[toff..toff + 3 * h];
            for block in data.chunks_exact_mut(step) {
                // Sub-blocks hold the residue-class DFTs in 2-bit-reversed
                // order: [E0, E2, E1, E3].
                let (e0, rest) = block.split_at_mut(h);
                let (e2, rest) = rest.split_at_mut(h);
                let (e1, e3) = rest.split_at_mut(h);
                for k in 0..h {
                    let (w1, w2, w3) = if CONJ {
                        (tw[3 * k].conj(), tw[3 * k + 1].conj(), tw[3 * k + 2].conj())
                    } else {
                        (tw[3 * k], tw[3 * k + 1], tw[3 * k + 2])
                    };
                    let a = e0[k];
                    let c = e2[k] * w2;
                    let b = e1[k] * w1;
                    let d = e3[k] * w3;
                    let t0 = a + c;
                    let t1 = a - c;
                    let t2 = b + d;
                    let t3 = b - d;
                    // ω^h = ∓i: rotate the odd difference by the sign's i.
                    let rot = if CONJ { t3.mul_i() } else { t3.mul_neg_i() };
                    e0[k] = t0 + t2;
                    e2[k] = t1 + rot;
                    e1[k] = t0 - t2;
                    e3[k] = t1 - rot;
                }
            }
            toff += 3 * h;
            h = step;
        }
        // lint: hot-loop-end
    }

    /// Strided-panel butterfly stages: identical arithmetic to
    /// [`Self::stages`], with row indices scaled by `stride` and every
    /// butterfly applied across the `cols` adjacent columns (one cache
    /// line when `cols == 4`).
    #[inline]
    fn stages_panel<const CONJ: bool>(
        &self,
        data: &mut [Complex64],
        stride: usize,
        cols: usize,
    ) {
        let n = self.n;
        let mut h = 1usize;
        if n.trailing_zeros() % 2 == 1 {
            let mut g = 0;
            while g < n {
                let r0 = g * stride;
                let r1 = r0 + stride;
                for c in 0..cols {
                    let a = data[r0 + c];
                    let b = data[r1 + c];
                    data[r0 + c] = a + b;
                    data[r1 + c] = a - b;
                }
                g += 2;
            }
            h = 2;
        }
        let mut toff = 0;
        // lint: hot-loop-begin
        while h < n {
            let step = 4 * h;
            let tw = &self.twiddles_neg[toff..toff + 3 * h];
            let mut g = 0;
            while g < n {
                for k in 0..h {
                    let (w1, w2, w3) = if CONJ {
                        (tw[3 * k].conj(), tw[3 * k + 1].conj(), tw[3 * k + 2].conj())
                    } else {
                        (tw[3 * k], tw[3 * k + 1], tw[3 * k + 2])
                    };
                    let i0 = (g + k) * stride;
                    let i2 = (g + h + k) * stride;
                    let i1 = (g + 2 * h + k) * stride;
                    let i3 = (g + 3 * h + k) * stride;
                    for c in 0..cols {
                        let a = data[i0 + c];
                        let cc = data[i2 + c] * w2;
                        let b = data[i1 + c] * w1;
                        let d = data[i3 + c] * w3;
                        let t0 = a + cc;
                        let t1 = a - cc;
                        let t2 = b + d;
                        let t3 = b - d;
                        let rot = if CONJ { t3.mul_i() } else { t3.mul_neg_i() };
                        data[i0 + c] = t0 + t2;
                        data[i2 + c] = t1 + rot;
                        data[i1 + c] = t0 - t2;
                        data[i3 + c] = t1 - rot;
                    }
                }
                g += step;
            }
            toff += 3 * h;
            h = step;
        }
        // lint: hot-loop-end
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::dft;
    use crate::fft::radix2::Radix2Plan;
    use crate::prng::Xoshiro256;

    fn random_signal(n: usize, seed: u64) -> Vec<Complex64> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n)
            .map(|_| Complex64::new(rng.next_signed(), rng.next_signed()))
            .collect()
    }

    #[test]
    fn matches_oracle_all_pow2_sizes() {
        for log in 0..=10 {
            let n = 1usize << log;
            let plan = Radix4Plan::new(n);
            for sign in [Sign::Negative, Sign::Positive] {
                let x = random_signal(n, 300 + log as u64);
                let want = dft(&x, sign);
                let mut got = x.clone();
                plan.process(&mut got, sign);
                for (a, b) in want.iter().zip(got.iter()) {
                    assert!((*a - *b).abs() < 1e-8 * (n as f64), "n={n} sign={sign:?}");
                }
            }
        }
    }

    #[test]
    fn agrees_with_radix2() {
        for &n in &[2usize, 8, 64, 512] {
            let r4 = Radix4Plan::new(n);
            let r2 = Radix2Plan::new(n);
            for sign in [Sign::Negative, Sign::Positive] {
                let x = random_signal(n, 40 + n as u64);
                let mut a = x.clone();
                let mut b = x;
                r4.process(&mut a, sign);
                r2.process(&mut b, sign);
                for (u, v) in a.iter().zip(b.iter()) {
                    assert!((*u - *v).abs() < 1e-9 * n as f64, "n={n} sign={sign:?}");
                }
            }
        }
    }

    #[test]
    fn roundtrip_scales_by_n() {
        for &n in &[8usize, 256, 1024] {
            let plan = Radix4Plan::new(n);
            let x = random_signal(n, 17);
            let mut y = x.clone();
            plan.process(&mut y, Sign::Negative);
            plan.process(&mut y, Sign::Positive);
            for (a, b) in x.iter().zip(y.iter()) {
                assert!((a.scale(n as f64) - *b).abs() < 1e-9 * n as f64);
            }
        }
    }

    #[test]
    fn panel_matches_contiguous() {
        // A panel of c columns inside an n×stride matrix must transform
        // each column exactly like the contiguous kernel.
        let n = 64;
        let stride = 7; // deliberately not a power of two
        let plan = Radix4Plan::new(n);
        for cols in 1..=4usize {
            for sign in [Sign::Negative, Sign::Positive] {
                let mut mat = random_signal(n * stride, cols as u64 * 91);
                let snapshot = mat.clone();
                plan.process_panel(&mut mat[2..], stride, cols, sign);
                for c in 0..cols {
                    let mut col: Vec<Complex64> =
                        (0..n).map(|r| snapshot[2 + r * stride + c]).collect();
                    plan.process(&mut col, sign);
                    for r in 0..n {
                        let got = mat[2 + r * stride + c];
                        assert!(
                            (got - col[r]).abs() < 1e-12 * n as f64,
                            "cols={cols} c={c} r={r} sign={sign:?}"
                        );
                    }
                }
                // Untouched columns stay bit-identical.
                for r in 0..n {
                    for c in cols..stride - 2 {
                        assert_eq!(
                            mat[2 + r * stride + c].re,
                            snapshot[2 + r * stride + c].re
                        );
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn rejects_non_pow2() {
        let _ = Radix4Plan::new(12);
    }
}

//! From-scratch FFT substrate.
//!
//! The paper uses FFTW's sequential 1-D FFT, composed into a 2-D transform
//! with OpenMP. There is no FFT crate in the vendored registry, so the
//! substrate is built here:
//!
//! * [`complex`] — a minimal `Complex64` value type.
//! * [`dft`] — the O(n²) direct DFT, used as the correctness oracle.
//! * [`split_radix`] — the split-radix-family radix-4 kernel for
//!   power-of-two sizes (the FSOFT grid size `2B` is a power of two for
//!   all paper bandwidths): half the butterfly passes of radix-2, plus
//!   the strided *panel* entry point the 2-D column pass runs on.
//! * [`radix2`] — iterative in-place radix-2 Cooley–Tukey, kept as the
//!   measurable baseline engine.
//! * [`real`] — real-input (conjugate-even) 1-D and 2-D transforms:
//!   ~half the work of the complex kernels on real SO(3) samples.
//! * [`bluestein`] — chirp-z fallback so arbitrary (non-power-of-two)
//!   bandwidths work too.
//! * [`plan`] — twiddle/bit-reversal caching and algorithm dispatch.
//! * [`fft2`] — the 2-D transform over the (α, γ) axes of one β-slice,
//!   with the copy-free panel column pass.
//!
//! Sign convention: `Sign::Negative` is the classical *forward* DFT
//! `X_k = Σ_j x_j e^{-2πi jk/n}`; `Sign::Positive` flips the exponent.
//! Neither direction normalizes — callers own the 1/n factors, because
//! the SO(3) quadrature absorbs all normalization into its own weights.

pub mod bluestein;
pub mod complex;
pub mod dft;
pub mod fft2;
pub mod plan;
pub mod radix2;
pub mod real;
pub(crate) mod simd;
pub mod split_radix;

pub use complex::Complex64;
pub use fft2::{ColumnPass, Fft2};
pub use plan::{FftAlgo, FftPlan, FftPlanner};
pub use real::{RealFft2, RealFftPlan};
pub use split_radix::Radix4Plan;

/// Executor-level FFT engine selection (see
/// [`crate::coordinator::ExecutorConfig::fft_engine`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum FftEngine {
    /// The overhauled engine: radix-4 (split-radix-family) butterflies
    /// with the copy-free panel column pass; Bluestein for
    /// non-power-of-two sizes. The default.
    #[default]
    SplitRadix,
    /// The pre-overhaul engine: radix-2 butterflies with the
    /// gather→FFT→scatter column sweep. Kept constructible so the
    /// speedup stays measurable (`benches/`, `BENCH_fft.json`).
    Radix2Baseline,
}

/// Exponent sign of the transform kernel `e^{sign · 2πi jk / n}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Sign {
    /// `e^{-2πi jk/n}` — the classical forward DFT.
    Negative,
    /// `e^{+2πi jk/n}` — the (unnormalized) inverse kernel.
    Positive,
}

impl Sign {
    /// The sign as a float factor on the angle.
    #[inline]
    pub fn factor(self) -> f64 {
        match self {
            Sign::Negative => -1.0,
            Sign::Positive => 1.0,
        }
    }

    /// The opposite sign.
    #[inline]
    pub fn flip(self) -> Sign {
        match self {
            Sign::Negative => Sign::Positive,
            Sign::Positive => Sign::Negative,
        }
    }
}

//! From-scratch FFT substrate.
//!
//! The paper uses FFTW's sequential 1-D FFT, composed into a 2-D transform
//! with OpenMP. There is no FFT crate in the vendored registry, so the
//! substrate is built here:
//!
//! * [`complex`] — a minimal `Complex64` value type.
//! * [`dft`] — the O(n²) direct DFT, used as the correctness oracle.
//! * [`radix2`] — iterative in-place radix-2 Cooley–Tukey for power-of-two
//!   sizes (the FSOFT grid size `2B` is a power of two for all paper
//!   bandwidths).
//! * [`bluestein`] — chirp-z fallback so arbitrary (non-power-of-two)
//!   bandwidths work too.
//! * [`plan`] — twiddle/bit-reversal caching and algorithm dispatch.
//! * [`fft2`] — the 2-D transform over the (α, γ) axes of one β-slice.
//!
//! Sign convention: `Sign::Negative` is the classical *forward* DFT
//! `X_k = Σ_j x_j e^{-2πi jk/n}`; `Sign::Positive` flips the exponent.
//! Neither direction normalizes — callers own the 1/n factors, because
//! the SO(3) quadrature absorbs all normalization into its own weights.

pub mod bluestein;
pub mod complex;
pub mod dft;
pub mod fft2;
pub mod plan;
pub mod radix2;

pub use complex::Complex64;
pub use plan::{FftPlan, FftPlanner};

/// Exponent sign of the transform kernel `e^{sign · 2πi jk / n}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Sign {
    /// `e^{-2πi jk/n}` — the classical forward DFT.
    Negative,
    /// `e^{+2πi jk/n}` — the (unnormalized) inverse kernel.
    Positive,
}

impl Sign {
    /// The sign as a float factor on the angle.
    #[inline]
    pub fn factor(self) -> f64 {
        match self {
            Sign::Negative => -1.0,
            Sign::Positive => 1.0,
        }
    }

    /// The opposite sign.
    #[inline]
    pub fn flip(self) -> Sign {
        match self {
            Sign::Negative => Sign::Positive,
            Sign::Positive => Sign::Negative,
        }
    }
}

//! Algorithm dispatch and plan caching.
//!
//! [`FftPlan`] picks radix-2 for power-of-two sizes (the common case:
//! the SO(3) grid edge `2B` is a power of two for all paper bandwidths)
//! and Bluestein otherwise. [`FftPlanner`] memoizes plans by size so the
//! twiddle tables are built once and shared (`Arc`) across worker threads.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::bluestein::BluesteinPlan;
use super::radix2::Radix2Plan;
use super::{Complex64, Sign};

/// A prepared 1-D transform of a fixed size.
#[derive(Debug, Clone)]
pub enum FftPlan {
    Radix2(Radix2Plan),
    Bluestein(BluesteinPlan),
}

impl FftPlan {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "FFT size must be >= 1");
        if n.is_power_of_two() {
            FftPlan::Radix2(Radix2Plan::new(n))
        } else {
            FftPlan::Bluestein(BluesteinPlan::new(n))
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        match self {
            FftPlan::Radix2(p) => p.len(),
            FftPlan::Bluestein(p) => p.len(),
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// In-place unnormalized transform.
    #[inline]
    pub fn process(&self, data: &mut [Complex64], sign: Sign) {
        match self {
            FftPlan::Radix2(p) => p.process(data, sign),
            FftPlan::Bluestein(p) => p.process(data, sign),
        }
    }
}

/// Thread-safe plan cache.
#[derive(Debug, Default)]
pub struct FftPlanner {
    cache: Mutex<HashMap<usize, Arc<FftPlan>>>,
}

impl FftPlanner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch (or build) the plan for size `n`.
    pub fn plan(&self, n: usize) -> Arc<FftPlan> {
        let mut cache = self.cache.lock().expect("planner poisoned");
        cache
            .entry(n)
            .or_insert_with(|| Arc::new(FftPlan::new(n)))
            .clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::dft;
    use crate::prng::Xoshiro256;

    #[test]
    fn dispatch_matches_oracle() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        for &n in &[8usize, 16, 10, 21] {
            let x: Vec<Complex64> = (0..n)
                .map(|_| Complex64::new(rng.next_signed(), rng.next_signed()))
                .collect();
            let plan = FftPlan::new(n);
            assert_eq!(plan.len(), n);
            let mut got = x.clone();
            plan.process(&mut got, Sign::Negative);
            let want = dft(&x, Sign::Negative);
            for (a, b) in want.iter().zip(got.iter()) {
                assert!((*a - *b).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn planner_caches_and_shares() {
        let planner = FftPlanner::new();
        let a = planner.plan(64);
        let b = planner.plan(64);
        assert!(Arc::ptr_eq(&a, &b));
        let c = planner.plan(128);
        assert_eq!(c.len(), 128);
    }

    #[test]
    fn planner_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<FftPlanner>();
        assert_send_sync::<Arc<FftPlan>>();
    }
}

//! Algorithm dispatch and plan caching.
//!
//! [`FftPlan`] picks the split-radix-family radix-4 kernel for
//! power-of-two sizes (the common case: the SO(3) grid edge `2B` is a
//! power of two for all paper bandwidths) and Bluestein otherwise; the
//! radix-2 kernel remains constructible via [`FftAlgo::Radix2`] as the
//! measurable baseline and as a fallback. [`FftPlanner`] memoizes plans
//! by size so the twiddle tables are built once and shared (`Arc`)
//! across worker threads.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::bluestein::BluesteinPlan;
use super::radix2::Radix2Plan;
use super::split_radix::Radix4Plan;
use super::{Complex64, Sign};
use crate::simd::SimdIsa;

/// Which 1-D kernel to build (see [`FftPlan::with_algo`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FftAlgo {
    /// Split-radix-family radix-4 for powers of two, Bluestein otherwise
    /// (the default dispatch).
    Auto,
    /// Force the radix-4 kernel (power-of-two sizes only).
    SplitRadix,
    /// The legacy dispatch: radix-2 for powers of two, Bluestein
    /// otherwise. Kept as the performance baseline.
    Radix2,
    /// Force the chirp-z kernel (any size).
    Bluestein,
}

/// A prepared 1-D transform of a fixed size.
#[derive(Debug, Clone)]
pub enum FftPlan {
    /// Radix-4 plan (power-of-two sizes).
    SplitRadix(Radix4Plan),
    /// Radix-2 plan (power-of-two sizes).
    Radix2(Radix2Plan),
    /// Bluestein chirp-z plan (any size).
    Bluestein(BluesteinPlan),
}

impl FftPlan {
    /// Default dispatch: radix-4 for powers of two, Bluestein otherwise.
    pub fn new(n: usize) -> Self {
        Self::with_algo(n, FftAlgo::Auto)
    }

    /// Build a specific kernel with the process-detected butterfly ISA.
    /// [`FftAlgo::SplitRadix`] panics on non-power-of-two sizes;
    /// [`FftAlgo::Radix2`] mirrors the legacy auto-dispatch (radix-2 /
    /// Bluestein).
    pub fn with_algo(n: usize, algo: FftAlgo) -> Self {
        Self::with_algo_isa(n, algo, crate::simd::detected_isa())
    }

    /// Build a specific kernel pinned to a butterfly ISA — the executor
    /// passes its plan-resolved `SimdPolicy` here so the FFT stage obeys
    /// the same dispatch axis as the DWT. Only the split-radix kernel
    /// carries vector stages; radix-2 and Bluestein stay scalar (they
    /// are baselines / fallbacks, not hot paths).
    pub fn with_algo_isa(n: usize, algo: FftAlgo, isa: SimdIsa) -> Self {
        assert!(n >= 1, "FFT size must be >= 1");
        match algo {
            FftAlgo::Auto => {
                if n.is_power_of_two() {
                    FftPlan::SplitRadix(Radix4Plan::with_isa(n, isa))
                } else {
                    FftPlan::Bluestein(BluesteinPlan::new(n))
                }
            }
            FftAlgo::SplitRadix => FftPlan::SplitRadix(Radix4Plan::with_isa(n, isa)),
            FftAlgo::Radix2 => {
                if n.is_power_of_two() {
                    FftPlan::Radix2(Radix2Plan::new(n))
                } else {
                    FftPlan::Bluestein(BluesteinPlan::new(n))
                }
            }
            FftAlgo::Bluestein => FftPlan::Bluestein(BluesteinPlan::new(n)),
        }
    }

    /// Transform size n.
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            FftPlan::SplitRadix(p) => p.len(),
            FftPlan::Radix2(p) => p.len(),
            FftPlan::Bluestein(p) => p.len(),
        }
    }

    /// Whether the transform size is zero.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The kernel this plan dispatches to (for diagnostics / bench labels).
    pub fn algo_name(&self) -> &'static str {
        match self {
            FftPlan::SplitRadix(_) => "split-radix",
            FftPlan::Radix2(_) => "radix2",
            FftPlan::Bluestein(_) => "bluestein",
        }
    }

    /// Whether [`Self::process_panel`] is available. Only the
    /// split-radix kernel carries strided butterflies: Bluestein's
    /// convolution cannot, and the radix-2 baseline deliberately keeps
    /// the pre-overhaul gather/scatter column pass (so the baseline
    /// measures the old engine, and no second panel kernel needs
    /// maintaining).
    #[inline]
    pub fn supports_panel(&self) -> bool {
        matches!(self, FftPlan::SplitRadix(_))
    }

    /// In-place unnormalized transform.
    #[inline]
    pub fn process(&self, data: &mut [Complex64], sign: Sign) {
        match self {
            FftPlan::SplitRadix(p) => p.process(data, sign),
            FftPlan::Radix2(p) => p.process(data, sign),
            FftPlan::Bluestein(p) => p.process(data, sign),
        }
    }

    /// In-place unnormalized transform of `cols` adjacent columns at
    /// `stride` (see [`Radix4Plan::process_panel`]). Panics for plans
    /// without strided butterflies — check [`Self::supports_panel`]
    /// first.
    #[inline]
    pub fn process_panel(
        &self,
        data: &mut [Complex64],
        stride: usize,
        cols: usize,
        sign: Sign,
    ) {
        match self {
            FftPlan::SplitRadix(p) => p.process_panel(data, stride, cols, sign),
            FftPlan::Radix2(_) | FftPlan::Bluestein(_) => {
                panic!("only split-radix plans have a strided panel kernel")
            }
        }
    }
}

/// Thread-safe plan cache (keyed by size; `Auto` dispatch).
#[derive(Debug, Default)]
pub struct FftPlanner {
    cache: Mutex<HashMap<usize, Arc<FftPlan>>>,
}

impl FftPlanner {
    /// An empty planner cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch (or build) the plan for size `n`.
    pub fn plan(&self, n: usize) -> Arc<FftPlan> {
        let mut cache = self.cache.lock().expect("planner poisoned");
        cache
            .entry(n)
            .or_insert_with(|| Arc::new(FftPlan::new(n)))
            .clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::dft;
    use crate::prng::Xoshiro256;

    #[test]
    fn dispatch_matches_oracle() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        for &n in &[8usize, 16, 10, 21] {
            let x: Vec<Complex64> = (0..n)
                .map(|_| Complex64::new(rng.next_signed(), rng.next_signed()))
                .collect();
            let plan = FftPlan::new(n);
            assert_eq!(plan.len(), n);
            assert_eq!(
                plan.algo_name(),
                if n.is_power_of_two() {
                    "split-radix"
                } else {
                    "bluestein"
                }
            );
            let mut got = x.clone();
            plan.process(&mut got, Sign::Negative);
            let want = dft(&x, Sign::Negative);
            for (a, b) in want.iter().zip(got.iter()) {
                assert!((*a - *b).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn all_algos_agree() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let n = 64;
        let x: Vec<Complex64> = (0..n)
            .map(|_| Complex64::new(rng.next_signed(), rng.next_signed()))
            .collect();
        let want = dft(&x, Sign::Positive);
        for algo in [
            FftAlgo::Auto,
            FftAlgo::SplitRadix,
            FftAlgo::Radix2,
            FftAlgo::Bluestein,
        ] {
            let plan = FftPlan::with_algo(n, algo);
            let mut got = x.clone();
            plan.process(&mut got, Sign::Positive);
            for (a, b) in want.iter().zip(got.iter()) {
                assert!((*a - *b).abs() < 1e-8, "{algo:?}");
            }
        }
    }

    #[test]
    fn legacy_algo_falls_back_to_bluestein() {
        let plan = FftPlan::with_algo(12, FftAlgo::Radix2);
        assert_eq!(plan.algo_name(), "bluestein");
        assert!(!plan.supports_panel());
        let plan = FftPlan::with_algo(16, FftAlgo::Radix2);
        assert_eq!(plan.algo_name(), "radix2");
        // The baseline keeps the gather/scatter column pass — only the
        // split-radix kernel carries strided panel butterflies.
        assert!(!plan.supports_panel());
        assert!(FftPlan::with_algo(16, FftAlgo::SplitRadix).supports_panel());
    }

    #[test]
    fn planner_caches_and_shares() {
        let planner = FftPlanner::new();
        let a = planner.plan(64);
        let b = planner.plan(64);
        assert!(Arc::ptr_eq(&a, &b));
        let c = planner.plan(128);
        assert_eq!(c.len(), 128);
    }

    #[test]
    fn planner_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<FftPlanner>();
        assert_send_sync::<Arc<FftPlan>>();
    }
}

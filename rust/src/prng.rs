//! Small, deterministic PRNGs.
//!
//! The vendored crate registry has no `rand`, so we carry our own:
//! [`SplitMix64`] for seeding and [`Xoshiro256`] (xoshiro256**) as the
//! workhorse generator. Both are well-studied, tiny, and fully
//! reproducible across platforms — which matters because the paper's
//! benchmark workload is "random complex Fourier coefficients, real and
//! imaginary parts uniform on [-1, 1]" and we want run-to-run stable
//! test fixtures.

/// SplitMix64: used to expand a single `u64` seed into a stream of
/// well-mixed values (and to seed [`Xoshiro256`]).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed the generator.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform double in [0, 1) with 53 bits of randomness.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform double in [-1, 1) — the paper's benchmark distribution.
    #[inline]
    pub fn next_signed(&mut self) -> f64 {
        2.0 * self.next_f64() - 1.0
    }

    /// Uniform integer in `[0, n)`. Uses the unbiased multiply-shift trick.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's method without the rejection loop is biased by at most
        // n / 2^64, which is negligible for test-sized n; keep the loop to
        // stay exactly uniform anyway.
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let wide = (r as u128) * (n as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform usize in `[lo, hi)` (half-open); requires `hi > lo`.
    #[inline]
    pub fn next_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.next_below((hi - lo) as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 (from the public-domain
        // reference implementation).
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        // Deterministic across runs/platforms.
        let mut sm2 = SplitMix64::new(1234567);
        let v2: Vec<u64> = (0..3).map(|_| sm2.next_u64()).collect();
        assert_eq!(v, v2);
        assert_ne!(v[0], v[1]);
    }

    #[test]
    fn xoshiro_uniform_range() {
        let mut rng = Xoshiro256::seed_from_u64(42);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = rng.next_signed();
            assert!((-1.0..1.0).contains(&y));
        }
    }

    #[test]
    fn xoshiro_next_below_bounds_and_coverage() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = rng.next_below(10) as usize;
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn xoshiro_mean_is_centered() {
        let mut rng = Xoshiro256::seed_from_u64(99);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_signed()).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean} too far from 0");
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}

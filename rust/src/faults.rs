//! Deterministic fault injection (failpoint-style) for chaos testing.
//!
//! Production code is littered with a handful of **named fault sites**
//! (plan build, Wigner table load, worker bodies, the batch runner, the
//! wisdom store, the service dispatcher). Each site calls [`fire`] and,
//! when a fault is armed for its name, applies the injected
//! [`FaultAction`] — a typed error, a panic, or a delay. The chaos suite
//! in `rust/tests/failure_injection.rs` and `serve-bench --inject` drive
//! these sites deterministically; see `docs/PERF.md` ("Failure semantics
//! & overload behavior").
//!
//! **Cost when disarmed:** [`fire`] is a single relaxed atomic load —
//! the sites stay in release builds but are runtime no-ops. Faults only
//! ever fire when explicitly armed, through one of:
//!
//! * the programmatic API ([`arm`] / [`arm_from_spec`] / [`ScopedFault`])
//!   — what the chaos tests and the `serve-bench --inject` flag use;
//! * the `SO3FT_FAULTS` environment variable, parsed once on first
//!   [`fire`] — **only** when the crate is compiled with the
//!   `fault-injection` feature, so a stray variable cannot destabilize a
//!   default-featured production binary.
//!
//! # Spec grammar (`--inject` / `SO3FT_FAULTS`)
//!
//! ```text
//! spec    := entry ( (';' | ',') entry )*
//! entry   := site '=' [ count '*' ] action
//! action  := 'err' [ '(' msg ')' ]     -- typed Error::FaultInjected
//!          | 'panic' [ '(' msg ')' ]   -- panic at the site
//!          | 'sleep' '(' millis ')'    -- delay, then proceed normally
//! ```
//!
//! `count` bounds the number of fires (the fault disarms itself after);
//! without it the fault fires on every hit. Examples:
//! `plan-build=err(chaos)`, `batch-runner=2*panic`,
//! `dispatcher=1*panic;wisdom-store=err`, `worker-body=sleep(5)`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::util::lock_unpoisoned as lock;

/// Site: [`So3Plan`](crate::transform::So3Plan) construction inside the
/// registry (`PlanRegistry::build`).
pub const PLAN_BUILD: &str = "plan-build";
/// Site: Wigner table build/load inside `Executor::new`.
pub const WIGNER_LOAD: &str = "wigner-load";
/// Site: top of every pool worker's region share (fires once per worker
/// per parallel region; infallible context, so `err` acts like `panic`).
pub const WORKER_BODY: &str = "worker-body";
/// Site: the service batch runner — once before the `*_batch_into` fast
/// path, then once per job on the per-job fallback reruns.
pub const BATCH_RUNNER: &str = "batch-runner";
/// Site: wisdom store file load (`err` degrades the store to Estimate
/// fallback exactly like an unreadable file; `panic` propagates).
pub const WISDOM_STORE: &str = "wisdom-store";
/// Site: the service dispatcher loop, after work is available but
/// **before** any job is dequeued — a panic here is recovered by the
/// watchdog without losing a single queued handle.
pub const DISPATCHER: &str = "dispatcher";

/// Every site name [`arm_from_spec`] accepts.
pub const SITES: &[&str] = &[
    PLAN_BUILD,
    WIGNER_LOAD,
    WORKER_BODY,
    BATCH_RUNNER,
    WISDOM_STORE,
    DISPATCHER,
];

/// What an armed fault does when its site fires.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultAction {
    /// Fail the site with [`Error::FaultInjected`] (at infallible sites
    /// this escalates to a panic).
    Err(String),
    /// Panic at the site.
    Panic(String),
    /// Sleep, then let the site proceed normally (latency injection).
    Sleep(Duration),
}

impl FaultAction {
    /// Apply at a `Result`-typed site: `Err` becomes a typed
    /// [`Error::FaultInjected`], `Panic` panics, `Sleep` delays and
    /// returns `Ok` so the site proceeds.
    pub fn apply(self, site: &str) -> Result<()> {
        match self {
            FaultAction::Err(msg) => Err(Error::FaultInjected {
                site: site.to_string(),
                msg,
            }),
            FaultAction::Panic(msg) => panic!("so3ft injected fault at {site}: {msg}"),
            FaultAction::Sleep(d) => {
                std::thread::sleep(d);
                Ok(())
            }
        }
    }

    /// Apply at an infallible site (no `Result` to thread an error
    /// through): `Err` escalates to a panic, `Panic` panics, `Sleep`
    /// delays.
    pub fn apply_infallible(self, site: &str) {
        match self {
            FaultAction::Err(msg) | FaultAction::Panic(msg) => {
                panic!("so3ft injected fault at {site}: {msg}")
            }
            FaultAction::Sleep(d) => std::thread::sleep(d),
        }
    }
}

struct ArmedFault {
    action: FaultAction,
    /// Remaining fires; `None` = unlimited.
    remaining: Option<u64>,
}

/// Number of currently armed sites — the disarmed fast path of [`fire`]
/// is this one relaxed load.
static ARMED: AtomicUsize = AtomicUsize::new(0);

fn registry() -> &'static Mutex<HashMap<String, ArmedFault>> {
    static REGISTRY: OnceLock<Mutex<HashMap<String, ArmedFault>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

#[cfg(feature = "fault-injection")]
fn arm_from_env_once() {
    static ENV_INIT: std::sync::Once = std::sync::Once::new();
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("SO3FT_FAULTS") {
            if !spec.trim().is_empty() {
                if let Err(e) = arm_from_spec(&spec) {
                    eprintln!("so3ft: ignoring SO3FT_FAULTS: {e}");
                }
            }
        }
    });
}

/// Poll a site. `None` (one relaxed load) unless a fault is armed for
/// `site`; otherwise the action to apply, decrementing a bounded count
/// (the fault disarms itself once its count is exhausted).
#[inline]
pub fn fire(site: &str) -> Option<FaultAction> {
    #[cfg(feature = "fault-injection")]
    arm_from_env_once();
    // ordering: Relaxed — ARMED is a hint; the registry mutex in
    // fire_slow is the real synchronization. A stale 0 only delays a
    // freshly armed fault by one poll, which the arm/fire API permits.
    if ARMED.load(Ordering::Relaxed) == 0 {
        return None;
    }
    fire_slow(site)
}

#[cold]
fn fire_slow(site: &str) -> Option<FaultAction> {
    let mut sites = lock(registry());
    let fault = sites.get_mut(site)?;
    let action = fault.action.clone();
    if let Some(rem) = &mut fault.remaining {
        *rem -= 1;
        if *rem == 0 {
            sites.remove(site);
            // ordering: Relaxed — published under the registry mutex;
            // ARMED is only ever a fast-path hint (see `fire`).
            ARMED.store(sites.len(), Ordering::Relaxed);
        }
    }
    Some(action)
}

/// Arm `site` with `action` for `count` fires (`None` = unlimited),
/// replacing any fault already armed there. Process-global: concurrent
/// tests sharing a site must serialize (see the chaos suite's lock).
pub fn arm(site: &str, action: FaultAction, count: Option<u64>) {
    if count == Some(0) {
        return;
    }
    let mut sites = lock(registry());
    sites.insert(
        site.to_string(),
        ArmedFault {
            action,
            remaining: count,
        },
    );
    // ordering: Relaxed — written under the registry mutex; readers that
    // miss the update (fast-path hint in `fire`) just poll again later.
    ARMED.store(sites.len(), Ordering::Relaxed);
}

/// Disarm one site (no-op if nothing is armed there).
pub fn disarm(site: &str) {
    let mut sites = lock(registry());
    sites.remove(site);
    // ordering: Relaxed — hint store under the registry mutex (see `arm`).
    ARMED.store(sites.len(), Ordering::Relaxed);
}

/// Disarm every site.
pub fn disarm_all() {
    let mut sites = lock(registry());
    sites.clear();
    // ordering: Relaxed — hint store under the registry mutex (see `arm`).
    ARMED.store(0, Ordering::Relaxed);
}

/// Whether a fault is currently armed for `site`.
pub fn is_armed(site: &str) -> bool {
    // ordering: Relaxed — fast-path hint; the mutex below is authoritative.
    if ARMED.load(Ordering::Relaxed) == 0 {
        return false;
    }
    lock(registry()).contains_key(site)
}

/// Parse a fault spec (see the [module docs](self) for the grammar) and
/// arm every entry. Unknown sites and malformed actions are typed
/// [`Error::Config`] errors; nothing is armed until the whole spec
/// parses.
pub fn arm_from_spec(spec: &str) -> Result<()> {
    for (site, action, count) in parse_spec(spec)? {
        arm(&site, action, count);
    }
    Ok(())
}

fn parse_spec(spec: &str) -> Result<Vec<(String, FaultAction, Option<u64>)>> {
    let mut out = Vec::new();
    for part in spec
        .split([';', ','])
        .map(str::trim)
        .filter(|s| !s.is_empty())
    {
        let bad = |detail: &str| Error::Config(format!("fault spec `{part}`: {detail}"));
        let (site, action) = part
            .split_once('=')
            .ok_or_else(|| bad("expected site=action"))?;
        let site = site.trim();
        if !SITES.contains(&site) {
            return Err(bad(&format!(
                "unknown site `{site}` (known: {})",
                SITES.join(", ")
            )));
        }
        let (count, kind) = match action.split_once('*') {
            Some((n, rest)) => {
                let n: u64 = n
                    .trim()
                    .parse()
                    .map_err(|_| bad(&format!("bad fire count `{}`", n.trim())))?;
                if n == 0 {
                    return Err(bad("fire count must be >= 1"));
                }
                (Some(n), rest.trim())
            }
            None => (None, action.trim()),
        };
        let (name, arg) = match kind.strip_suffix(')') {
            Some(prefix) => match prefix.split_once('(') {
                Some((name, arg)) => (name.trim(), Some(arg)),
                None => return Err(bad("unbalanced parentheses")),
            },
            None => (kind, None),
        };
        let action = match name {
            "err" => FaultAction::Err(arg.unwrap_or("injected").to_string()),
            "panic" => FaultAction::Panic(arg.unwrap_or("injected").to_string()),
            "sleep" => {
                let ms: u64 = arg
                    .ok_or_else(|| bad("sleep needs milliseconds: sleep(MS)"))?
                    .trim()
                    .parse()
                    .map_err(|_| bad("sleep needs integer milliseconds"))?;
                FaultAction::Sleep(Duration::from_millis(ms))
            }
            other => {
                return Err(bad(&format!(
                    "unknown action `{other}` (err | panic | sleep)"
                )))
            }
        };
        out.push((site.to_string(), action, count));
    }
    if out.is_empty() {
        return Err(Error::Config("fault spec is empty".into()));
    }
    Ok(out)
}

/// RAII guard arming a fault for a scope: arms on construction, disarms
/// its site on drop (even across a test panic). The registry is
/// process-global — tests that share sites must serialize.
pub struct ScopedFault {
    site: &'static str,
}

impl ScopedFault {
    /// Arm `site` with `action` for the guard's lifetime; `count` bounds
    /// how many times it fires (`None` = unlimited).
    pub fn new(site: &'static str, action: FaultAction, count: Option<u64>) -> Self {
        arm(site, action, count);
        Self { site }
    }
}

impl Drop for ScopedFault {
    fn drop(&mut self) {
        disarm(self.site);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Unit tests fire only made-up site names so they cannot interfere
    // with other lib tests exercising the real sites in this process.

    #[test]
    fn disarmed_site_is_a_no_op() {
        assert!(fire("unit-test-never-armed").is_none());
    }

    #[test]
    fn count_limited_fault_disarms_itself() {
        arm("unit-test-count", FaultAction::Err("boom".into()), Some(2));
        assert!(is_armed("unit-test-count"));
        assert!(matches!(fire("unit-test-count"), Some(FaultAction::Err(_))));
        assert!(fire("unit-test-count").is_some());
        assert!(fire("unit-test-count").is_none(), "count exhausted");
        assert!(!is_armed("unit-test-count"));
    }

    #[test]
    fn scoped_fault_disarms_on_drop() {
        {
            let _guard =
                ScopedFault::new("unit-test-scoped", FaultAction::Sleep(Duration::ZERO), None);
            assert!(is_armed("unit-test-scoped"));
        }
        assert!(!is_armed("unit-test-scoped"));
    }

    #[test]
    fn spec_grammar_parses_actions_counts_and_messages() {
        let spec = "plan-build=err(chaos); batch-runner=2*panic,dispatcher=sleep(15)";
        let parsed = parse_spec(spec).unwrap();
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed[0].0, PLAN_BUILD);
        assert_eq!(parsed[0].1, FaultAction::Err("chaos".into()));
        assert_eq!(parsed[0].2, None);
        assert_eq!(parsed[1].0, BATCH_RUNNER);
        assert_eq!(parsed[1].1, FaultAction::Panic("injected".into()));
        assert_eq!(parsed[1].2, Some(2));
        assert_eq!(parsed[2].1, FaultAction::Sleep(Duration::from_millis(15)));
    }

    #[test]
    fn spec_grammar_rejects_malformed_entries() {
        for bad in [
            "",
            "plan-build",
            "no-such-site=err",
            "plan-build=explode",
            "plan-build=0*err",
            "plan-build=x*err",
            "plan-build=sleep",
            "plan-build=sleep(ms)",
            "plan-build=err(unbalanced",
        ] {
            assert!(
                matches!(parse_spec(bad), Err(Error::Config(_))),
                "spec {bad:?} must be rejected"
            );
        }
    }

    #[test]
    fn apply_maps_err_to_typed_error_and_sleep_to_ok() {
        let e = FaultAction::Err("msg".into()).apply("some-site").unwrap_err();
        match e {
            Error::FaultInjected { site, msg } => {
                assert_eq!(site, "some-site");
                assert_eq!(msg, "msg");
            }
            other => panic!("expected FaultInjected, got {other:?}"),
        }
        assert!(FaultAction::Sleep(Duration::ZERO).apply("s").is_ok());
    }

    #[test]
    fn apply_panic_panics_with_site_in_message() {
        let err = std::panic::catch_unwind(|| {
            FaultAction::Panic("kaboom".into()).apply("site-x").unwrap();
        })
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("site-x") && msg.contains("kaboom"), "{msg}");
    }
}

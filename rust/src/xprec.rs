//! Double-double ("dd") extended-precision arithmetic.
//!
//! The paper switches from double to 80-bit x86 extended precision for the
//! DWT/iDWT at bandwidth 512 ("double precision is not sufficient").
//! Rust has no portable `long double`, so the same role is filled by
//! error-free-transform double-double arithmetic (~106 bits of mantissa,
//! i.e. *more* than the paper's 64-bit extended mantissa). It is used in
//! the Wigner-d recurrence and the DWT accumulation when
//! `Precision::Extended` is selected in the transform config.

use std::ops::{Add, Div, Mul, Neg, Sub};

/// An unevaluated sum `hi + lo` with |lo| ≤ ulp(hi)/2.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Dd {
    /// High (leading) component.
    pub hi: f64,
    /// Low (error) component; `hi + lo` is the represented value.
    pub lo: f64,
}

/// Error-free sum of two doubles (Knuth two-sum).
#[inline]
fn two_sum(a: f64, b: f64) -> (f64, f64) {
    let s = a + b;
    let bb = s - a;
    let err = (a - (s - bb)) + (b - bb);
    (s, err)
}

/// Error-free sum when |a| ≥ |b| (fast two-sum).
#[inline]
fn quick_two_sum(a: f64, b: f64) -> (f64, f64) {
    let s = a + b;
    let err = b - (s - a);
    (s, err)
}

/// Error-free product via FMA.
#[inline]
fn two_prod(a: f64, b: f64) -> (f64, f64) {
    let p = a * b;
    let err = a.mul_add(b, -p);
    (p, err)
}

impl Dd {
    /// Double-double zero.
    pub const ZERO: Dd = Dd { hi: 0.0, lo: 0.0 };
    /// Double-double one.
    pub const ONE: Dd = Dd { hi: 1.0, lo: 0.0 };

    /// Widen an `f64` (exact).
    #[inline]
    pub fn from_f64(x: f64) -> Self {
        Dd { hi: x, lo: 0.0 }
    }

    /// Renormalized construction from an unevaluated pair.
    #[inline]
    pub fn from_parts(hi: f64, lo: f64) -> Self {
        let (s, e) = quick_two_sum(hi, lo);
        Dd { hi: s, lo: e }
    }

    /// Round back to the nearest `f64`.
    #[inline]
    pub fn to_f64(self) -> f64 {
        self.hi + self.lo
    }

    /// Absolute value.
    #[inline]
    pub fn abs(self) -> Dd {
        if self.hi < 0.0 || (self.hi == 0.0 && self.lo < 0.0) {
            -self
        } else {
            self
        }
    }

    /// Multiply-accumulate `self + a*b`, all in dd precision.
    #[inline]
    pub fn mul_add(self, a: Dd, b: Dd) -> Dd {
        self + a * b
    }

    /// dd * f64 (cheaper than full dd*dd).
    #[inline]
    pub fn mul_f64(self, b: f64) -> Dd {
        let (p, e) = two_prod(self.hi, b);
        Dd::from_parts(p, e + self.lo * b)
    }

    /// dd + f64.
    #[inline]
    pub fn add_f64(self, b: f64) -> Dd {
        let (s, e) = two_sum(self.hi, b);
        Dd::from_parts(s, e + self.lo)
    }

    /// Square root (Newton step on the double estimate).
    pub fn sqrt(self) -> Dd {
        if self.hi == 0.0 {
            return Dd::ZERO;
        }
        assert!(self.hi > 0.0, "dd sqrt of negative value");
        let x = 1.0 / self.hi.sqrt();
        let ax = self.hi * x;
        let d = self - Dd::from_f64(ax) * Dd::from_f64(ax);
        Dd::from_parts(ax, d.hi * (x * 0.5))
    }
}

impl Add for Dd {
    type Output = Dd;
    #[inline]
    fn add(self, o: Dd) -> Dd {
        let (s, e) = two_sum(self.hi, o.hi);
        Dd::from_parts(s, e + self.lo + o.lo)
    }
}

impl Sub for Dd {
    type Output = Dd;
    #[inline]
    fn sub(self, o: Dd) -> Dd {
        self + (-o)
    }
}

impl Mul for Dd {
    type Output = Dd;
    #[inline]
    fn mul(self, o: Dd) -> Dd {
        let (p, e) = two_prod(self.hi, o.hi);
        Dd::from_parts(p, e + self.hi * o.lo + self.lo * o.hi)
    }
}

impl Div for Dd {
    type Output = Dd;
    #[inline]
    fn div(self, o: Dd) -> Dd {
        // One Newton refinement of the double quotient.
        let q1 = self.hi / o.hi;
        let r = self - o.mul_f64(q1);
        let q2 = r.hi / o.hi;
        let r2 = r - o.mul_f64(q2);
        let q3 = r2.hi / o.hi;
        Dd::from_parts(q1, q2).add_f64(q3)
    }
}

impl Neg for Dd {
    type Output = Dd;
    #[inline]
    fn neg(self) -> Dd {
        Dd {
            hi: -self.hi,
            lo: -self.lo,
        }
    }
}

/// A complex number with dd components — for the extended-precision DWT
/// accumulation.
#[derive(Debug, Clone, Copy, Default)]
pub struct DdComplex {
    /// Real part.
    pub re: Dd,
    /// Imaginary part.
    pub im: Dd,
}

// SAFETY: four f64s, no drop glue, any bit pattern valid.
unsafe impl crate::util::Pod for DdComplex {}

impl DdComplex {
    /// Double-double complex zero.
    pub const ZERO: DdComplex = DdComplex {
        re: Dd::ZERO,
        im: Dd::ZERO,
    };

    /// Widen an `(re, im)` pair (exact).
    #[inline]
    pub fn from_f64(re: f64, im: f64) -> Self {
        Self {
            re: Dd::from_f64(re),
            im: Dd::from_f64(im),
        }
    }

    /// `self += z * s` with f64 scalar s and f64 complex z — the hot
    /// accumulation shape of the extended DWT.
    #[inline]
    pub fn acc_scaled(&mut self, re: f64, im: f64, s: f64) {
        self.re = self.re + Dd::from_f64(re).mul_f64(s);
        self.im = self.im + Dd::from_f64(im).mul_f64(s);
    }

    /// Round both components back to `f64`.
    #[inline]
    pub fn to_f64(self) -> (f64, f64) {
        (self.re.to_f64(), self.im.to_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_recovers_lost_bits() {
        // 1 + 1e-20 is exactly 1.0 in f64; dd keeps the tail.
        let x = Dd::from_f64(1.0).add_f64(1e-20);
        assert_eq!(x.hi, 1.0);
        assert!((x.lo - 1e-20).abs() < 1e-35);
        let y = x - Dd::from_f64(1.0);
        assert!((y.to_f64() - 1e-20).abs() < 1e-35);
    }

    #[test]
    fn mul_exactness() {
        // (1 + 2^-40)² = 1 + 2^-39 + 2^-80; f64 drops the last term.
        let a = Dd::from_f64(1.0).add_f64((2.0f64).powi(-40));
        let sq = a * a;
        let expect_lo = (2.0f64).powi(-80);
        let diff = sq - Dd::from_f64(1.0) - Dd::from_f64((2.0f64).powi(-39));
        assert!((diff.to_f64() - expect_lo).abs() < 1e-40);
    }

    #[test]
    fn div_roundtrip() {
        let a = Dd::from_f64(std::f64::consts::PI);
        let b = Dd::from_f64(std::f64::consts::E);
        let q = a / b;
        let back = q * b;
        assert!((back - a).abs().to_f64() < 1e-30);
    }

    #[test]
    fn sqrt_squares_back() {
        for &x in &[2.0f64, 3.0, 1e10, 1e-10, 0.5] {
            let s = Dd::from_f64(x).sqrt();
            let diff = (s * s - Dd::from_f64(x)).abs().to_f64();
            assert!(diff < 1e-28 * x.max(1.0), "x={x} diff={diff}");
        }
        assert_eq!(Dd::ZERO.sqrt().to_f64(), 0.0);
    }

    #[test]
    fn dd_sum_beats_f64_on_cancellation() {
        // Kahan-style stress: Σ (1e16, 1.0, -1e16) repeated — f64 loses the
        // ones, dd keeps them.
        let mut dd = Dd::ZERO;
        let mut plain = 0.0f64;
        for _ in 0..1000 {
            for &v in &[1e16, 1.0, -1e16] {
                dd = dd.add_f64(v);
                plain += v;
            }
        }
        assert!((dd.to_f64() - 1000.0).abs() < 1e-9);
        // Document that plain f64 actually fails here (guards the test's
        // own meaningfulness; 1e16 + 1 == 1e16 exactly... the increment
        // is below one ulp of 1e16 ⇒ plain sum is exactly 0).
        assert!(plain.abs() < 1e-6 || (plain - 1000.0).abs() > 1.0);
    }

    #[test]
    fn complex_accumulation() {
        let mut acc = DdComplex::ZERO;
        for i in 0..100 {
            acc.acc_scaled(1e15, -1e15, 1.0);
            acc.acc_scaled(-1e15, 1e15, 1.0);
            acc.acc_scaled(0.5, 0.25, (i % 2) as f64 * 2.0 - 1.0);
        }
        let (re, im) = acc.to_f64();
        assert!((re - 0.0).abs() < 1e-12);
        assert!((im - 0.0).abs() < 1e-12);
    }
}

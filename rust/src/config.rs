//! Configuration system: a TOML-subset parser (no external deps are
//! available offline) plus the typed [`RunConfig`] the CLI and launcher
//! consume.
//!
//! Supported syntax — the subset real deployments need:
//! ```toml
//! # comments
//! [transform]
//! bandwidth = 16
//! threads = 4
//! schedule = "dynamic:1"
//! strategy = "geometric"      # geometric | sigma | nosym
//! algorithm = "matvec-folded" # matvec-folded | matvec | clenshaw
//! storage = "precomputed"     # precomputed | onthefly | auto
//! precision = "double"        # double | extended
//! fft = "split-radix"         # split-radix | radix2-baseline
//! simd = "auto"               # auto | scalar | force-avx2 | force-neon
//! real_input = false          # conjugate-even forward FFT stage
//! pool = "owned"              # owned | global (persistent worker pool)
//!
//! [memory]
//! budget = "auto"             # auto | unlimited | bytes:N | <MiB>
//!
//! [service]
//! threads = 4                 # worker-pool size (0 = machine parallelism)
//! batch_window_us = 200       # micro-batch window, microseconds (0 = off)
//! registry_budget_mb = 2048   # LRU plan-cache budget (omit = unbounded)
//! max_batch = 32              # jobs per micro-batch
//! max_queue = 256             # admission cap on queued jobs (omit = unlimited)
//! max_inflight_bytes = 1073741824 # admission cap on in-flight payload bytes
//! default_deadline_ms = 5000  # deadline for jobs that set none (omit = none)
//! tenant_quota = 8            # per-tenant in-flight job cap (omit = none)
//!
//! [runtime]
//! artifacts = "artifacts"
//! use_xla = false
//!
//! [wisdom]
//! rigor = "estimate"          # estimate | measure (plan auto-tuning)
//! time_budget_ms = 250        # per-plan measurement budget
//! cache_path = "wisdom.so3wis" # omit = the shared cache dir (util::cache_file)
//! ```
//!
//! Unknown sections and unknown keys are **typed errors**, not silently
//! ignored — a typo'd knob must never quietly fall back to a default.

use std::collections::HashMap;
use std::path::Path;

use crate::coordinator::{ExecutorConfig, MemoryBudget, PartitionStrategy};
use crate::dwt::tables::{WignerStorage, WignerTables};
use crate::dwt::{DwtAlgorithm, Precision};
use crate::error::{Error, Result};
use crate::fft::FftEngine;
use crate::pool::{PoolSpec, Schedule};
use crate::simd::SimdPolicy;
use crate::wisdom::PlanRigor;

/// Raw parsed file: section → key → value (strings unquoted).
#[derive(Debug, Clone, Default)]
pub struct ParsedConfig {
    sections: HashMap<String, HashMap<String, String>>,
}

impl ParsedConfig {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut sections: HashMap<String, HashMap<String, String>> = HashMap::new();
        let mut current = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                current = name.trim().to_string();
                sections.entry(current.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = k.trim().to_string();
                let mut value = v.trim().to_string();
                if value.len() >= 2 && value.starts_with('"') && value.ends_with('"') {
                    value = value[1..value.len() - 1].to_string();
                }
                sections.entry(current.clone()).or_default().insert(key, value);
            } else {
                return Err(Error::Config(format!(
                    "line {}: expected `key = value` or `[section]`, got {line:?}",
                    lineno + 1
                )));
            }
        }
        Ok(Self { sections })
    }

    /// Parse a config file from disk.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Raw string value for `key` in `[section]`, if present.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    /// Integer value for `key` in `[section]`; `Err` on a non-integer.
    pub fn get_usize(&self, section: &str, key: &str) -> Result<Option<usize>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                Error::Config(format!("[{section}] {key}: expected integer, got {v:?}"))
            }),
        }
    }

    /// Boolean value for `key` in `[section]`; `Err` unless `true`/`false`.
    pub fn get_bool(&self, section: &str, key: &str) -> Result<Option<bool>> {
        match self.get(section, key) {
            None => Ok(None),
            Some("true") => Ok(Some(true)),
            Some("false") => Ok(Some(false)),
            Some(v) => Err(Error::Config(format!(
                "[{section}] {key}: expected true/false, got {v:?}"
            ))),
        }
    }
}

/// `[service]` section: how a [`crate::service::So3Service`] built from
/// this config is shaped (worker-pool size, plan-registry budget,
/// micro-batch window).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServiceSettings {
    /// Worker-pool size; 0 = the machine's available parallelism.
    pub threads: usize,
    /// Micro-batch window in microseconds (0 disables the wait; jobs
    /// already queued under one key still coalesce).
    pub batch_window_us: u64,
    /// Plan-registry LRU budget over `table_bytes()`, in MiB
    /// (`None` = unbounded).
    pub registry_budget_mb: Option<usize>,
    /// Upper bound on jobs per micro-batch.
    pub max_batch: usize,
    /// Admission cap on queued jobs (`None` = unlimited).
    pub max_queue: Option<usize>,
    /// Admission cap on in-flight payload bytes (`None` = unlimited).
    pub max_inflight_bytes: Option<usize>,
    /// Deadline applied to jobs that set none, in milliseconds
    /// (`None` = jobs without an explicit deadline never expire).
    pub default_deadline_ms: Option<u64>,
    /// Per-tenant in-flight job cap (`None` = no quota).
    pub tenant_quota: Option<usize>,
}

impl Default for ServiceSettings {
    fn default() -> Self {
        Self {
            threads: 0,
            batch_window_us: 0,
            registry_budget_mb: None,
            max_batch: 32,
            max_queue: None,
            max_inflight_bytes: None,
            default_deadline_ms: None,
            tenant_quota: None,
        }
    }
}

impl ServiceSettings {
    /// Start an [`crate::service::So3ServiceBuilder`] from these
    /// settings (callers can chain further overrides before `build`).
    pub fn to_builder(&self) -> crate::service::So3ServiceBuilder {
        let mut builder = crate::service::So3Service::builder()
            .batch_window(std::time::Duration::from_micros(self.batch_window_us))
            .max_batch(self.max_batch);
        if self.threads > 0 {
            builder = builder.threads(self.threads);
        }
        if let Some(mb) = self.registry_budget_mb {
            builder = builder.registry_budget_bytes(mb << 20);
        }
        if let Some(q) = self.max_queue {
            builder = builder.max_queue(q);
        }
        if let Some(bytes) = self.max_inflight_bytes {
            builder = builder.max_inflight_bytes(bytes);
        }
        if let Some(ms) = self.default_deadline_ms {
            builder = builder.default_deadline(std::time::Duration::from_millis(ms));
        }
        if let Some(q) = self.tenant_quota {
            builder = builder.tenant_quota(q);
        }
        builder
    }
}

/// `[wisdom]` section: planner rigor and wisdom-store placement (see
/// [`crate::wisdom`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WisdomSettings {
    /// Plan-building rigor (default: zero-cost `estimate`).
    pub rigor: PlanRigor,
    /// Explicit wisdom-file path (`None` = the shared cache dir,
    /// [`crate::util::cache_file`]`("wisdom.so3wis")`).
    pub cache_path: Option<String>,
    /// Per-plan measurement budget in milliseconds.
    pub time_budget_ms: u64,
}

impl Default for WisdomSettings {
    fn default() -> Self {
        Self {
            rigor: PlanRigor::Estimate,
            cache_path: None,
            time_budget_ms: 250,
        }
    }
}

/// Fully-resolved run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Transform bandwidth B.
    pub bandwidth: usize,
    /// Executor knobs (threads, schedule, partition, DWT backend).
    pub exec: ExecutorConfig,
    /// Serving-layer settings (queue bounds, batch window, deadlines).
    pub service: ServiceSettings,
    /// Auto-tuning (wisdom) settings.
    pub wisdom: WisdomSettings,
    /// Directory holding AOT-compiled XLA artifacts.
    pub artifacts_dir: String,
    /// Route the DWT through the XLA runtime backend.
    pub use_xla: bool,
    /// Seed for reproducible test payloads.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            bandwidth: 16,
            exec: ExecutorConfig::default(),
            service: ServiceSettings::default(),
            wisdom: WisdomSettings::default(),
            artifacts_dir: "artifacts".into(),
            use_xla: false,
            seed: 42,
        }
    }
}

/// Parse a storage spec: `precomputed | onthefly | auto[:budget_mb]`.
pub fn parse_storage(s: &str, b: usize) -> Result<WignerStorage> {
    match s {
        "precomputed" => Ok(WignerStorage::Precomputed),
        "onthefly" => Ok(WignerStorage::OnTheFly),
        _ if s.starts_with("auto") => {
            let budget_mb = s
                .strip_prefix("auto:")
                .map(|v| v.parse::<usize>())
                .transpose()
                .map_err(|_| Error::Config(format!("bad auto budget in {s:?}")))?
                .unwrap_or(2048);
            let _ = WignerTables::storage_len(b);
            Ok(WignerStorage::auto(b, budget_mb << 20))
        }
        _ => Err(Error::Config(format!(
            "storage: expected precomputed|onthefly|auto, got {s:?}"
        ))),
    }
}

/// Parse an algorithm spec.
pub fn parse_algorithm(s: &str) -> Result<DwtAlgorithm> {
    match s {
        "matvec-folded" | "matvecfolded" | "folded" => Ok(DwtAlgorithm::MatVecFolded),
        "matvec" => Ok(DwtAlgorithm::MatVec),
        "clenshaw" => Ok(DwtAlgorithm::Clenshaw),
        _ => Err(Error::Config(format!(
            "algorithm: expected matvec-folded|matvec|clenshaw, got {s:?}"
        ))),
    }
}

/// Parse a precision spec.
pub fn parse_precision(s: &str) -> Result<Precision> {
    match s {
        "double" => Ok(Precision::Double),
        "extended" => Ok(Precision::Extended),
        _ => Err(Error::Config(format!(
            "precision: expected double|extended, got {s:?}"
        ))),
    }
}

/// Parse an FFT engine spec.
pub fn parse_fft_engine(s: &str) -> Result<FftEngine> {
    match s {
        "split-radix" | "splitradix" => Ok(FftEngine::SplitRadix),
        "radix2-baseline" | "radix2" => Ok(FftEngine::Radix2Baseline),
        _ => Err(Error::Config(format!(
            "fft: expected split-radix|radix2-baseline, got {s:?}"
        ))),
    }
}

/// Parse a planner rigor spec.
pub fn parse_rigor(s: &str) -> Result<PlanRigor> {
    PlanRigor::parse(s)
        .ok_or_else(|| Error::Config(format!("rigor: expected estimate|measure, got {s:?}")))
}

/// Every section/key `from_parsed` understands; anything else is a typed
/// config error.
const KNOWN_KEYS: &[(&str, &[&str])] = &[
    (
        "transform",
        &[
            "bandwidth",
            "threads",
            "schedule",
            "strategy",
            "algorithm",
            "storage",
            "precision",
            "fft",
            "simd",
            "real_input",
            "pool",
        ],
    ),
    ("memory", &["budget"]),
    (
        "service",
        &[
            "threads",
            "batch_window_us",
            "registry_budget_mb",
            "max_batch",
            "max_queue",
            "max_inflight_bytes",
            "default_deadline_ms",
            "tenant_quota",
        ],
    ),
    ("runtime", &["artifacts", "use_xla"]),
    ("run", &["seed"]),
    ("wisdom", &["rigor", "cache_path", "time_budget_ms"]),
];

impl RunConfig {
    /// Build from a parsed file, applying defaults for missing keys and
    /// rejecting unknown sections/keys with a typed error.
    pub fn from_parsed(p: &ParsedConfig) -> Result<Self> {
        for (section, keys) in &p.sections {
            let known = KNOWN_KEYS
                .iter()
                .find(|(name, _)| name == section)
                .ok_or_else(|| {
                    Error::Config(format!(
                        "unknown section [{section}] (known: transform, memory, \
                         service, runtime, run, wisdom)"
                    ))
                })?;
            for key in keys.keys() {
                if !known.1.contains(&key.as_str()) {
                    return Err(Error::Config(format!(
                        "[{section}] unknown key {key:?} (known: {})",
                        known.1.join(", ")
                    )));
                }
            }
        }
        let mut cfg = RunConfig::default();
        if let Some(b) = p.get_usize("transform", "bandwidth")? {
            cfg.bandwidth = b;
        }
        if let Some(t) = p.get_usize("transform", "threads")? {
            cfg.exec.threads = t;
        }
        if let Some(s) = p.get("transform", "schedule") {
            cfg.exec.schedule = Schedule::parse(s)
                .ok_or_else(|| Error::Config(format!("bad schedule {s:?}")))?;
        }
        if let Some(s) = p.get("transform", "strategy") {
            cfg.exec.strategy = PartitionStrategy::parse(s)
                .ok_or_else(|| Error::Config(format!("bad strategy {s:?}")))?;
        }
        if let Some(s) = p.get("transform", "algorithm") {
            cfg.exec.algorithm = parse_algorithm(s)?;
        }
        if let Some(s) = p.get("transform", "storage") {
            cfg.exec.storage = parse_storage(s, cfg.bandwidth)?;
        }
        if let Some(s) = p.get("transform", "precision") {
            cfg.exec.precision = parse_precision(s)?;
        }
        if let Some(s) = p.get("transform", "fft") {
            cfg.exec.fft_engine = parse_fft_engine(s)?;
        }
        if let Some(s) = p.get("transform", "simd") {
            cfg.exec.simd = SimdPolicy::parse(s)?;
        }
        if let Some(v) = p.get_bool("transform", "real_input")? {
            cfg.exec.real_input = v;
        }
        if let Some(s) = p.get("transform", "pool") {
            cfg.exec.pool = PoolSpec::parse(s)
                .ok_or_else(|| Error::Config(format!("bad pool {s:?}")))?;
        }
        if let Some(s) = p.get("memory", "budget") {
            cfg.exec.memory = MemoryBudget::parse(s).ok_or_else(|| {
                Error::Config(format!(
                    "[memory] budget: expected auto|unlimited|bytes:N|MiB, got {s:?}"
                ))
            })?;
        }
        if let Some(t) = p.get_usize("service", "threads")? {
            cfg.service.threads = t;
        }
        if let Some(w) = p.get_usize("service", "batch_window_us")? {
            cfg.service.batch_window_us = w as u64;
        }
        if let Some(mb) = p.get_usize("service", "registry_budget_mb")? {
            cfg.service.registry_budget_mb = Some(mb);
        }
        if let Some(m) = p.get_usize("service", "max_batch")? {
            if m == 0 {
                return Err(Error::Config("[service] max_batch: must be >= 1".into()));
            }
            cfg.service.max_batch = m;
        }
        if let Some(q) = p.get_usize("service", "max_queue")? {
            cfg.service.max_queue = Some(q);
        }
        if let Some(bytes) = p.get_usize("service", "max_inflight_bytes")? {
            cfg.service.max_inflight_bytes = Some(bytes);
        }
        if let Some(ms) = p.get_usize("service", "default_deadline_ms")? {
            cfg.service.default_deadline_ms = Some(ms as u64);
        }
        if let Some(q) = p.get_usize("service", "tenant_quota")? {
            cfg.service.tenant_quota = Some(q);
        }
        if let Some(s) = p.get("runtime", "artifacts") {
            cfg.artifacts_dir = s.to_string();
        }
        if let Some(v) = p.get_bool("runtime", "use_xla")? {
            cfg.use_xla = v;
        }
        if let Some(s) = p.get_usize("run", "seed")? {
            cfg.seed = s as u64;
        }
        if let Some(s) = p.get("wisdom", "rigor") {
            cfg.wisdom.rigor = parse_rigor(s)?;
        }
        if let Some(s) = p.get("wisdom", "cache_path") {
            cfg.wisdom.cache_path = Some(s.to_string());
        }
        if let Some(ms) = p.get_usize("wisdom", "time_budget_ms")? {
            cfg.wisdom.time_budget_ms = ms as u64;
        }
        Ok(cfg)
    }

    /// Load and resolve a run configuration from a config file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Self::from_parsed(&ParsedConfig::load(path)?)
    }

    /// Serialize back to the TOML subset [`ParsedConfig`] reads — every
    /// key `from_parsed` understands appears, so
    /// `from_parsed(parse(to_toml))` round-trips the full configuration.
    /// (A `PoolSpec::Shared` handle is process-local and serializes as
    /// `"owned"`.)
    pub fn to_toml(&self) -> String {
        use crate::wisdom::store::{algorithm_name, fft_engine_name};
        let storage = match self.exec.storage {
            WignerStorage::Precomputed => "precomputed",
            WignerStorage::OnTheFly => "onthefly",
        };
        let precision = match self.exec.precision {
            Precision::Double => "double",
            Precision::Extended => "extended",
        };
        let pool = match self.exec.pool {
            PoolSpec::Global => "global",
            // Owned is the default; a Shared handle cannot outlive the
            // process, so it degrades to the default.
            PoolSpec::Owned | PoolSpec::Shared(_) => "owned",
        };
        let mut out = String::new();
        out.push_str("[transform]\n");
        out.push_str(&format!("bandwidth = {}\n", self.bandwidth));
        out.push_str(&format!("threads = {}\n", self.exec.threads));
        out.push_str(&format!("schedule = \"{}\"\n", self.exec.schedule.name()));
        out.push_str(&format!("strategy = \"{}\"\n", self.exec.strategy.name()));
        out.push_str(&format!(
            "algorithm = \"{}\"\n",
            algorithm_name(self.exec.algorithm)
        ));
        out.push_str(&format!("storage = \"{storage}\"\n"));
        out.push_str(&format!("precision = \"{precision}\"\n"));
        out.push_str(&format!(
            "fft = \"{}\"\n",
            fft_engine_name(self.exec.fft_engine)
        ));
        out.push_str(&format!("simd = \"{}\"\n", self.exec.simd.name()));
        out.push_str(&format!("real_input = {}\n", self.exec.real_input));
        out.push_str(&format!("pool = \"{pool}\"\n"));
        out.push_str("\n[memory]\n");
        out.push_str(&format!("budget = \"{}\"\n", self.exec.memory.name()));
        out.push_str("\n[service]\n");
        out.push_str(&format!("threads = {}\n", self.service.threads));
        out.push_str(&format!(
            "batch_window_us = {}\n",
            self.service.batch_window_us
        ));
        if let Some(mb) = self.service.registry_budget_mb {
            out.push_str(&format!("registry_budget_mb = {mb}\n"));
        }
        out.push_str(&format!("max_batch = {}\n", self.service.max_batch));
        if let Some(q) = self.service.max_queue {
            out.push_str(&format!("max_queue = {q}\n"));
        }
        if let Some(bytes) = self.service.max_inflight_bytes {
            out.push_str(&format!("max_inflight_bytes = {bytes}\n"));
        }
        if let Some(ms) = self.service.default_deadline_ms {
            out.push_str(&format!("default_deadline_ms = {ms}\n"));
        }
        if let Some(q) = self.service.tenant_quota {
            out.push_str(&format!("tenant_quota = {q}\n"));
        }
        out.push_str("\n[runtime]\n");
        out.push_str(&format!("artifacts = \"{}\"\n", self.artifacts_dir));
        out.push_str(&format!("use_xla = {}\n", self.use_xla));
        out.push_str("\n[run]\n");
        out.push_str(&format!("seed = {}\n", self.seed));
        out.push_str("\n[wisdom]\n");
        out.push_str(&format!("rigor = \"{}\"\n", self.wisdom.rigor.name()));
        if let Some(path) = &self.wisdom.cache_path {
            out.push_str(&format!("cache_path = \"{path}\"\n"));
        }
        out.push_str(&format!(
            "time_budget_ms = {}\n",
            self.wisdom.time_budget_ms
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# sample config
[transform]
bandwidth = 8
threads = 3
schedule = "dynamic:2"
strategy = "sigma"
algorithm = "clenshaw"
storage = "onthefly"
precision = "double"
fft = "radix2-baseline"
simd = "scalar"
real_input = true
pool = "global"

[memory]
budget = "bytes:123456789"

[service]
threads = 3
batch_window_us = 250
registry_budget_mb = 64
max_batch = 8
max_queue = 128
max_inflight_bytes = 1048576
default_deadline_ms = 2500
tenant_quota = 4

[runtime]
artifacts = "my-artifacts"
use_xla = true

[run]
seed = 7

[wisdom]
rigor = "measure"
cache_path = "/tmp/w.so3wis"
time_budget_ms = 125
"#;

    #[test]
    fn parses_full_sample() {
        let cfg = RunConfig::from_parsed(&ParsedConfig::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.bandwidth, 8);
        assert_eq!(cfg.exec.threads, 3);
        assert_eq!(cfg.exec.schedule, Schedule::Dynamic { chunk: 2 });
        assert_eq!(cfg.exec.strategy, PartitionStrategy::SigmaClustered);
        assert_eq!(cfg.exec.algorithm, DwtAlgorithm::Clenshaw);
        assert_eq!(cfg.exec.storage, WignerStorage::OnTheFly);
        assert_eq!(cfg.exec.fft_engine, FftEngine::Radix2Baseline);
        assert_eq!(cfg.exec.simd, SimdPolicy::Scalar);
        assert!(cfg.exec.real_input);
        assert!(matches!(cfg.exec.pool, PoolSpec::Global));
        assert_eq!(cfg.exec.memory, MemoryBudget::Bytes(123456789));
        assert_eq!(
            cfg.service,
            ServiceSettings {
                threads: 3,
                batch_window_us: 250,
                registry_budget_mb: Some(64),
                max_batch: 8,
                max_queue: Some(128),
                max_inflight_bytes: Some(1048576),
                default_deadline_ms: Some(2500),
                tenant_quota: Some(4),
            }
        );
        assert_eq!(cfg.artifacts_dir, "my-artifacts");
        assert!(cfg.use_xla);
        assert_eq!(cfg.seed, 7);
        assert_eq!(
            cfg.wisdom,
            WisdomSettings {
                rigor: PlanRigor::Measure,
                cache_path: Some("/tmp/w.so3wis".into()),
                time_budget_ms: 125,
            }
        );
    }

    /// `ExecutorConfig` has no `PartialEq`; compare the exec fields one
    /// by one.
    fn assert_same(a: &RunConfig, b: &RunConfig) {
        assert_eq!(a.bandwidth, b.bandwidth);
        assert_eq!(a.exec.threads, b.exec.threads);
        assert_eq!(a.exec.schedule, b.exec.schedule);
        assert_eq!(a.exec.strategy, b.exec.strategy);
        assert_eq!(a.exec.algorithm, b.exec.algorithm);
        assert_eq!(a.exec.storage, b.exec.storage);
        assert_eq!(a.exec.precision, b.exec.precision);
        assert_eq!(a.exec.fft_engine, b.exec.fft_engine);
        assert_eq!(a.exec.simd, b.exec.simd);
        assert_eq!(a.exec.real_input, b.exec.real_input);
        assert_eq!(a.exec.pool.name(), b.exec.pool.name());
        assert_eq!(a.exec.memory, b.exec.memory);
        assert_eq!(a.service, b.service);
        assert_eq!(a.wisdom, b.wisdom);
        assert_eq!(a.artifacts_dir, b.artifacts_dir);
        assert_eq!(a.use_xla, b.use_xla);
        assert_eq!(a.seed, b.seed);
    }

    #[test]
    fn full_roundtrip_parse_serialize_parse() {
        // Non-default value for every key the parser understands.
        let first = RunConfig::from_parsed(&ParsedConfig::parse(SAMPLE).unwrap()).unwrap();
        let second =
            RunConfig::from_parsed(&ParsedConfig::parse(&first.to_toml()).unwrap()).unwrap();
        assert_same(&first, &second);
        // Defaults round-trip too (registry_budget_mb/cache_path omitted).
        let dflt = RunConfig::default();
        let back = RunConfig::from_parsed(&ParsedConfig::parse(&dflt.to_toml()).unwrap()).unwrap();
        assert_same(&dflt, &back);
        assert!(back.service.registry_budget_mb.is_none());
        assert!(back.wisdom.cache_path.is_none());
    }

    #[test]
    fn unknown_sections_and_keys_are_typed_errors() {
        let err = RunConfig::from_parsed(
            &ParsedConfig::parse("[transfrom]\nbandwidth = 8").unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown section"), "{err}");
        let err = RunConfig::from_parsed(
            &ParsedConfig::parse("[transform]\nbandwith = 8").unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown key"), "{err}");
        let err = RunConfig::from_parsed(
            &ParsedConfig::parse("[wisdom]\nbudget = 10").unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("time_budget_ms"), "{err}");
    }

    #[test]
    fn wisdom_section_validation() {
        let cfg = RunConfig::from_parsed(&ParsedConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.wisdom, WisdomSettings::default());
        assert_eq!(cfg.wisdom.rigor, PlanRigor::Estimate);
        assert!(RunConfig::from_parsed(
            &ParsedConfig::parse("[wisdom]\nrigor = \"exhaustive\"").unwrap()
        )
        .is_err());
        assert!(RunConfig::from_parsed(
            &ParsedConfig::parse("[wisdom]\ntime_budget_ms = \"fast\"").unwrap()
        )
        .is_err());
    }

    #[test]
    fn service_defaults_and_validation() {
        let cfg = RunConfig::from_parsed(&ParsedConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.service, ServiceSettings::default());
        assert!(RunConfig::from_parsed(
            &ParsedConfig::parse("[service]\nmax_batch = 0").unwrap()
        )
        .is_err());
        assert!(RunConfig::from_parsed(
            &ParsedConfig::parse("[service]\nthreads = \"many\"").unwrap()
        )
        .is_err());
        // Settings expand into a working service builder.
        let cfg = RunConfig::from_parsed(
            &ParsedConfig::parse("[service]\nthreads = 2\nbatch_window_us = 100").unwrap(),
        )
        .unwrap();
        let service = cfg.service.to_builder().build().unwrap();
        assert_eq!(service.threads(), 2);
        // Overload knobs flow config -> settings -> builder -> admission.
        let cfg = RunConfig::from_parsed(
            &ParsedConfig::parse("[service]\nthreads = 1\nmax_queue = 0").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.service.max_queue, Some(0));
        let service = cfg.service.to_builder().build().unwrap();
        let spec = crate::service::JobSpec::forward(4);
        let input =
            crate::service::JobInput::Grid(crate::so3::sampling::So3Grid::zeros(4).unwrap());
        match service.submit(spec, input) {
            Err(crate::error::Error::Overloaded { .. }) => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }

    #[test]
    fn defaults_apply_when_missing() {
        let cfg = RunConfig::from_parsed(&ParsedConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.bandwidth, 16);
        assert_eq!(cfg.exec.threads, 1);
        assert!(matches!(cfg.exec.pool, PoolSpec::Owned));
    }

    #[test]
    fn bad_pool_spec_is_an_error() {
        assert!(RunConfig::from_parsed(
            &ParsedConfig::parse("[transform]\npool = \"rented\"").unwrap()
        )
        .is_err());
    }

    #[test]
    fn comments_and_whitespace_tolerated() {
        let p = ParsedConfig::parse("  # lead\n[a]\n x = 1  # trail\n\n y = \"s\"\n").unwrap();
        assert_eq!(p.get("a", "x"), Some("1"));
        assert_eq!(p.get("a", "y"), Some("s"));
    }

    #[test]
    fn bad_lines_are_errors() {
        assert!(ParsedConfig::parse("nonsense line").is_err());
        assert!(RunConfig::from_parsed(
            &ParsedConfig::parse("[transform]\nschedule = \"bogus\"").unwrap()
        )
        .is_err());
        assert!(RunConfig::from_parsed(
            &ParsedConfig::parse("[transform]\nthreads = \"x\"").unwrap()
        )
        .is_err());
    }

    #[test]
    fn algorithm_specs_parse() {
        assert_eq!(
            parse_algorithm("matvec-folded").unwrap(),
            DwtAlgorithm::MatVecFolded
        );
        assert_eq!(parse_algorithm("folded").unwrap(), DwtAlgorithm::MatVecFolded);
        assert_eq!(parse_algorithm("matvec").unwrap(), DwtAlgorithm::MatVec);
        assert_eq!(parse_algorithm("clenshaw").unwrap(), DwtAlgorithm::Clenshaw);
        assert!(parse_algorithm("fused").is_err());
        // Defaults flow through `from_parsed`.
        let cfg = RunConfig::from_parsed(
            &ParsedConfig::parse("[transform]\nalgorithm = \"matvec-folded\"").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.exec.algorithm, DwtAlgorithm::MatVecFolded);
    }

    #[test]
    fn simd_key_parses_and_defaults() {
        let cfg = RunConfig::from_parsed(&ParsedConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.exec.simd, SimdPolicy::Auto);
        let cfg = RunConfig::from_parsed(
            &ParsedConfig::parse("[transform]\nsimd = \"scalar\"").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.exec.simd, SimdPolicy::Scalar);
        // Force* variants parse at the config layer (host support is
        // checked at plan build, not parse time).
        let cfg = RunConfig::from_parsed(
            &ParsedConfig::parse("[transform]\nsimd = \"force-avx2\"").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.exec.simd, SimdPolicy::ForceAvx2);
        assert!(RunConfig::from_parsed(
            &ParsedConfig::parse("[transform]\nsimd = \"sse9\"").unwrap()
        )
        .is_err());
    }

    #[test]
    fn fft_engine_parses() {
        assert_eq!(parse_fft_engine("split-radix").unwrap(), FftEngine::SplitRadix);
        assert_eq!(
            parse_fft_engine("radix2-baseline").unwrap(),
            FftEngine::Radix2Baseline
        );
        assert!(parse_fft_engine("fftw").is_err());
    }

    #[test]
    fn memory_budget_key_parses_and_defaults() {
        let cfg = RunConfig::from_parsed(&ParsedConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.exec.memory, MemoryBudget::Auto);
        let cfg = RunConfig::from_parsed(
            &ParsedConfig::parse("[memory]\nbudget = \"unlimited\"").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.exec.memory, MemoryBudget::Unlimited);
        // A bare integer is MiB, matching the CLI flag.
        let cfg = RunConfig::from_parsed(
            &ParsedConfig::parse("[memory]\nbudget = \"64\"").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.exec.memory, MemoryBudget::Bytes(64 << 20));
        assert!(RunConfig::from_parsed(
            &ParsedConfig::parse("[memory]\nbudget = \"lots\"").unwrap()
        )
        .is_err());
        assert!(RunConfig::from_parsed(
            &ParsedConfig::parse("[memory]\ncap = 1").unwrap()
        )
        .is_err());
    }

    #[test]
    fn storage_auto_parses() {
        assert_eq!(parse_storage("auto:1", 64).unwrap(), WignerStorage::OnTheFly);
        assert_eq!(
            parse_storage("auto:100000", 8).unwrap(),
            WignerStorage::Precomputed
        );
        assert!(parse_storage("auto:x", 8).is_err());
        assert!(parse_storage("weird", 8).is_err());
    }
}

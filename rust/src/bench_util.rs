//! Benchmark harness utilities (criterion is not in the vendored
//! registry, so the benches carry their own timing/statistics/reporting
//! substrate).
//!
//! Conventions shared by all benches under `rust/benches/`:
//! * warm up once, then take `reps` timed samples;
//! * report min / median / mean ± std;
//! * print paper-style tables to stdout and, when `SO3FT_BENCH_CSV` is
//!   set, append machine-readable rows to `bench_results/<name>.csv`.

use std::time::Instant;

/// Summary statistics over timed samples (seconds).
#[derive(Debug, Clone)]
pub struct Samples {
    /// Raw per-repetition wall times, in seconds.
    pub seconds: Vec<f64>,
}

impl Samples {
    /// Fastest repetition.
    pub fn min(&self) -> f64 {
        self.seconds.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Arithmetic mean of the repetitions.
    pub fn mean(&self) -> f64 {
        self.seconds.iter().sum::<f64>() / self.seconds.len() as f64
    }

    /// Sample standard deviation of the repetitions.
    pub fn std(&self) -> f64 {
        if self.seconds.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self
            .seconds
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / (self.seconds.len() - 1) as f64;
        var.sqrt()
    }

    /// Median repetition time.
    pub fn median(&self) -> f64 {
        let mut v = self.seconds.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        if n % 2 == 1 {
            v[n / 2]
        } else {
            0.5 * (v[n / 2 - 1] + v[n / 2])
        }
    }
}

/// Time `f` with one warm-up call and `reps` samples.
pub fn time_fn<F: FnMut()>(reps: usize, mut f: F) -> Samples {
    f(); // warm-up
    let mut seconds = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        seconds.push(t0.elapsed().as_secs_f64());
    }
    Samples { seconds }
}

/// Pretty seconds: 1.234 s / 12.3 ms / 45.6 µs.
pub fn fmt_seconds(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Mean ± std in the paper's `(a ± b)E-k` style.
pub fn fmt_mean_std_sci(mean: f64, std: f64) -> String {
    if mean == 0.0 {
        return "0".to_string();
    }
    let exp = mean.abs().log10().floor() as i32;
    let scale = 10f64.powi(exp);
    format!("({:.2} ± {:.2})E{exp:+03}", mean / scale, std / scale)
}

/// A simple aligned-column table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row; must have as many cells as there are headers.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    /// Render the table to stdout with aligned columns.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut out = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                out.push_str(&format!("{c:>w$}  ", w = w));
            }
            println!("{}", out.trim_end());
        };
        line(&self.headers);
        println!(
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("--")
        );
        for row in &self.rows {
            line(row);
        }
    }
}

/// Append CSV rows to `bench_results/<name>.csv` when SO3FT_BENCH_CSV is
/// set (header written on creation).
pub fn csv_sink(name: &str, header: &str, rows: &[String]) {
    if std::env::var("SO3FT_BENCH_CSV").is_err() {
        return;
    }
    let dir = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{name}.csv"));
    let fresh = !path.exists();
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .expect("csv open");
    if fresh {
        writeln!(f, "{header}").unwrap();
    }
    for r in rows {
        writeln!(f, "{r}").unwrap();
    }
}

/// Escape a string for embedding in a JSON document.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Write a machine-readable benchmark report
/// `{"meta": {...}, "records": [...]}` to `path` (no serde offline, so
/// `meta` values and `records` entries must already be valid JSON
/// fragments — numbers, quoted strings, or objects). Used by
/// `examples/e2e_benchmark.rs` to emit `BENCH_fft.json`, the repo's
/// tracked perf trajectory.
pub fn write_json_report(
    path: &str,
    meta: &[(&str, String)],
    records: &[String],
) -> std::io::Result<()> {
    use std::io::Write;
    let mut out = String::from("{\n  \"meta\": {");
    for (i, (k, v)) in meta.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\n    \"{}\": {v}", json_escape(k)));
    }
    out.push_str("\n  },\n  \"records\": [");
    for (i, r) in records.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\n    {r}"));
    }
    out.push_str("\n  ]\n}\n");
    let mut f = std::fs::File::create(path)?;
    f.write_all(out.as_bytes())
}

/// Append records to an existing [`write_json_report`]-format file (the
/// CLI's `serve-bench --json` merges its `service_*` records into the
/// `BENCH_fft.json` the e2e benchmark wrote earlier in the same CI job).
/// Creates a fresh report when the file is absent; a file that does not
/// end with the report's closing `]\n}` is refused (typed `InvalidData`)
/// rather than corrupted.
pub fn append_json_records(path: &str, records: &[String]) -> std::io::Result<()> {
    use std::io::Write;
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return write_json_report(path, &[("bench", "\"service\"".to_string())], records)
        }
        Err(e) => return Err(e),
    };
    const TAIL: &str = "\n  ]\n}\n";
    let Some(pos) = text.rfind(TAIL) else {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("{path} is not a write_json_report file (missing closing `]}}`)"),
        ));
    };
    let empty_array = text[..pos].trim_end().ends_with('[');
    let mut insert = String::new();
    for (i, r) in records.iter().enumerate() {
        if i > 0 || !empty_array {
            insert.push(',');
        }
        insert.push_str(&format!("\n    {r}"));
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(text[..pos].as_bytes())?;
    f.write_all(insert.as_bytes())?;
    f.write_all(TAIL.as_bytes())
}

/// Read an env-var override for bench scale (small by default so `cargo
/// bench` completes quickly; CI/full runs can raise it).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Parse an env-var list like "8 16 32".
pub fn env_usize_list(name: &str, default: &[usize]) -> Vec<usize> {
    match std::env::var(name) {
        Ok(s) => s
            .replace(',', " ")
            .split_whitespace()
            .filter_map(|t| t.parse().ok())
            .collect(),
        Err(_) => default.to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Samples {
            seconds: vec![1.0, 2.0, 3.0, 4.0],
        };
        assert_eq!(s.min(), 1.0);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.median() - 2.5).abs() < 1e-12);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn time_fn_counts_reps() {
        let mut calls = 0;
        let s = time_fn(5, || calls += 1);
        assert_eq!(calls, 6); // warm-up + 5
        assert_eq!(s.seconds.len(), 5);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_seconds(2.5), "2.500 s");
        assert_eq!(fmt_seconds(0.0025), "2.50 ms");
        assert_eq!(fmt_seconds(2.5e-6), "2.50 µs");
        assert!(fmt_mean_std_sci(1.1e-14, 1.4e-15).starts_with("(1.10 ± 0.14)E-14"));
    }

    #[test]
    fn json_report_roundtrips_textually() {
        let dir = std::env::temp_dir().join("so3ft_json_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("report.json");
        let meta = [("bench", "\"fft\"".to_string()), ("threads", "4".to_string())];
        let records = ["{\"b\": 32, \"seconds\": 1.5e-3}".to_string()];
        write_json_report(path.to_str().unwrap(), &meta, &records).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"bench\": \"fft\""));
        assert!(text.contains("\"records\""));
        assert!(text.contains("1.5e-3"));
        assert_eq!(json_escape("a\"b\\c\n"), "a\\\"b\\\\c\\n");
    }

    #[test]
    fn append_json_records_merges_and_creates() {
        let dir = std::env::temp_dir().join(format!("so3ft_json_append_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("merge.json");
        let path_s = path.to_str().unwrap();
        let _ = std::fs::remove_file(&path);
        // Absent file → fresh report.
        append_json_records(path_s, &["{\"kind\": \"a\", \"v\": 1}".to_string()]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"kind\": \"a\""));
        // Existing report → records appended, earlier ones kept.
        append_json_records(path_s, &["{\"kind\": \"b\", \"v\": 2}".to_string()]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"kind\": \"a\"") && text.contains("\"kind\": \"b\""));
        // Still a well-formed report: exactly one records array, with a
        // comma between the two entries.
        assert_eq!(text.matches("\"records\"").count(), 1);
        assert!(text.contains("\"v\": 1},\n    {\"kind\": \"b\""));
        // Appending into an empty records array needs no leading comma.
        write_json_report(path_s, &[("bench", "\"x\"".to_string())], &[]).unwrap();
        append_json_records(path_s, &["{\"kind\": \"c\"}".to_string()]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("[\n    {\"kind\": \"c\"}\n  ]"));
        // Garbage input is refused, not corrupted.
        std::fs::write(&path, "not json").unwrap();
        assert!(append_json_records(path_s, &["{}".to_string()]).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn env_list_parsing() {
        std::env::set_var("SO3FT_TEST_LIST_X", "4, 8 16");
        assert_eq!(env_usize_list("SO3FT_TEST_LIST_X", &[1]), vec![4, 8, 16]);
        assert_eq!(env_usize_list("SO3FT_TEST_NOPE_X", &[1, 2]), vec![1, 2]);
    }
}

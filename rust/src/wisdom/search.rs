//! The measured planner search: simulate every candidate knob setting
//! with the `simulator/` cost model, then wall-clock only the top few
//! on the plan's own worker pool.
//!
//! The candidate space is the cross product of the crate's tunable
//! axes — DWT algorithm × FFT engine × loop schedule (including the
//! partition chunk) × partition strategy × SIMD policy — 120
//! combinations. Timing all
//! of them would make `PlanRigor::Measure` cost seconds per build, so
//! the discrete-event machine model ranks them first (per-package DWT
//! flop counts from the real `TransformPlan`, coarse static rates per
//! engine) and only the `TOP_K` simulated leaders are measured with
//! short calibrated repetitions of the real `Executor` entry points.
//! Simulation mis-ranks by at most a few percent here; it only has to
//! keep the true winner inside the top-k, not order it first.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::{Executor, ExecutorConfig, PartitionStrategy, TransformPlan};
use crate::dwt::DwtAlgorithm;
use crate::error::Result;
use crate::fft::FftEngine;
use crate::pool::{PoolSpec, Schedule, WorkerPool};
use crate::simd::{SimdIsa, SimdPolicy};
use crate::simulator::machine::{simulate_transform, MachineParams, RegionSpec, TransformSpec};
use crate::so3::coeffs::So3Coeffs;
use crate::so3::sampling::So3Grid;

/// One point of the search space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    /// Loop-scheduling policy.
    pub schedule: Schedule,
    /// Order-domain partition strategy.
    pub strategy: PartitionStrategy,
    /// DWT algorithm choice.
    pub algorithm: DwtAlgorithm,
    /// 1-D FFT engine.
    pub fft_engine: FftEngine,
    /// SIMD dispatch policy.
    pub simd: SimdPolicy,
}

/// What the search measured: the winning candidate with its best
/// per-direction wall times, plus the worker pool the measurements ran
/// on (substituted into the tuned plan so the timed substrate and the
/// serving substrate are the same object).
#[derive(Debug, Clone)]
pub(crate) struct SearchOutcome {
    pub winner: Candidate,
    pub fwd_seconds: f64,
    pub inv_seconds: f64,
    /// Pool created for the measurement when the base config asked for
    /// an owned pool — reused by the final plan instead of re-spawning.
    pub shared_pool: Option<Arc<WorkerPool>>,
}

/// Candidates actually wall-clocked after the simulator ranking.
const TOP_K: usize = 3;
/// Repetition cap per candidate (the budget cuts this short).
const MAX_REPS: usize = 5;

/// Coarse per-flop rates (seconds) for the simulator ranking. Absolute
/// values only scale the ranking; the *ratios* between engines are what
/// order the candidates, and those come from the crate's own ablation
/// benches (folded ≈ 0.6× matvec, clenshaw ≈ 1.15×; radix-2 baseline
/// ≈ 1.45× split-radix).
const DWT_RATE: f64 = 1.5e-9;
const FFT_RATE: f64 = 1.2e-9;

fn algorithm_multiplier(a: DwtAlgorithm) -> f64 {
    match a {
        DwtAlgorithm::MatVecFolded => 0.6,
        DwtAlgorithm::MatVec => 1.0,
        DwtAlgorithm::Clenshaw => 1.15,
    }
}

fn fft_multiplier(e: FftEngine) -> f64 {
    match e {
        FftEngine::SplitRadix => 1.0,
        FftEngine::Radix2Baseline => 1.45,
    }
}

/// Ranking discount for the vector kernels. Only `Auto` on a host where
/// detection actually found an ISA is faster than scalar; everywhere
/// else the two policies run the same code and must tie (a fake
/// discount would waste a `TOP_K` measurement slot on a duplicate).
fn simd_multiplier(p: SimdPolicy) -> f64 {
    match p {
        SimdPolicy::Auto if crate::simd::detected_isa() != SimdIsa::Scalar => 0.65,
        _ => 1.0,
    }
}

/// The full candidate space (120 combinations).
pub fn candidate_space() -> Vec<Candidate> {
    let schedules = [
        Schedule::Dynamic { chunk: 1 },
        Schedule::Dynamic { chunk: 4 },
        Schedule::Static,
        Schedule::StaticInterleaved,
        Schedule::Guided { min_chunk: 1 },
    ];
    let strategies = [
        PartitionStrategy::GeometricClustered,
        PartitionStrategy::SigmaClustered,
    ];
    let algorithms = [
        DwtAlgorithm::MatVecFolded,
        DwtAlgorithm::MatVec,
        DwtAlgorithm::Clenshaw,
    ];
    let engines = [FftEngine::SplitRadix, FftEngine::Radix2Baseline];
    let simd_policies = [SimdPolicy::Scalar, SimdPolicy::Auto];
    let mut out = Vec::with_capacity(120);
    for &algorithm in &algorithms {
        for &fft_engine in &engines {
            for &simd in &simd_policies {
                for &schedule in &schedules {
                    for &strategy in &strategies {
                        out.push(Candidate {
                            schedule,
                            strategy,
                            algorithm,
                            fft_engine,
                            simd,
                        });
                    }
                }
            }
        }
    }
    out
}

/// Simulated wall time of one candidate at `threads` virtual cores.
fn simulated_seconds(b: usize, cand: &Candidate, threads: usize) -> f64 {
    let plan = TransformPlan::new(b, cand.strategy);
    let mult = algorithm_multiplier(cand.algorithm) * simd_multiplier(cand.simd) * DWT_RATE;
    let dwt = RegionSpec {
        costs: plan
            .package_flops()
            .iter()
            .map(|&f| f as f64 * mult)
            .collect(),
        mem_fraction: 0.55,
        schedule: cand.schedule,
    };
    // FFT stage: 2·(2B)² 1-D FFTs of length 2B, ~5·n·log₂n flops each,
    // split into 2B equal row-block packages.
    let n = 2 * b;
    let fft_flops = 2.0 * (n * n) as f64 * 5.0 * n as f64 * (n as f64).log2();
    let fft_cost = fft_flops * FFT_RATE * fft_multiplier(cand.fft_engine)
        * simd_multiplier(cand.simd)
        / n as f64;
    let fft = RegionSpec {
        costs: vec![fft_cost; n],
        mem_fraction: 0.30,
        schedule: cand.schedule,
    };
    let spec = TransformSpec {
        regions: vec![dwt, fft],
        serial: 0.0,
        label: String::new(),
    };
    simulate_transform(&spec, threads.max(1), &MachineParams::opteron_like())
}

/// Run the measured search for `(b, base config)` within `budget`.
///
/// The base config's `storage`, `precision`, `real_input`, and
/// `threads` are held fixed (they are correctness/accuracy choices, not
/// speed knobs); only the five candidate axes vary.
pub(crate) fn search(
    b: usize,
    base: &ExecutorConfig,
    budget: Duration,
) -> Result<SearchOutcome> {
    let mut scored: Vec<(f64, Candidate)> = candidate_space()
        .into_iter()
        .map(|c| (simulated_seconds(b, &c, base.threads), c))
        .collect();
    scored.sort_by(|x, y| x.0.total_cmp(&y.0));
    let ranked: Vec<Candidate> = scored.into_iter().take(TOP_K).map(|(_, c)| c).collect();

    // One measurement substrate for every candidate: the plan's own
    // pool when shared/global, otherwise a single pool spawned here and
    // handed to the final plan (per-candidate owned pools would time
    // thread spawning, not transforms).
    let (pool_spec, shared_pool) = if base.threads == 1 {
        (base.pool.clone(), None)
    } else {
        match &base.pool {
            PoolSpec::Owned => {
                let pool = Arc::new(WorkerPool::new(base.threads)?);
                (PoolSpec::Shared(Arc::clone(&pool)), Some(pool))
            }
            spec => (spec.clone(), None),
        }
    };

    let coeffs = So3Coeffs::random(b, 0x5EED_0003);
    let per_candidate = budget.div_f64(ranked.len().max(1) as f64);
    let mut best: Option<(Candidate, f64, f64)> = None;
    for cand in &ranked {
        let config = ExecutorConfig {
            threads: base.threads,
            schedule: cand.schedule,
            strategy: cand.strategy,
            algorithm: cand.algorithm,
            storage: base.storage,
            precision: base.precision,
            fft_engine: cand.fft_engine,
            real_input: base.real_input,
            simd: cand.simd,
            pool: pool_spec.clone(),
        };
        let exec = Executor::new(b, config)?;
        let mut ws = exec.make_workspace();
        let mut grid = So3Grid::zeros(b)?;
        let mut back = So3Coeffs::zeros(b);
        let (mut inv_best, mut fwd_best) = (f64::INFINITY, f64::INFINITY);
        let started = Instant::now();
        for rep in 0..MAX_REPS {
            if rep > 0 && started.elapsed() >= per_candidate {
                break;
            }
            let t = Instant::now();
            exec.inverse_into(&coeffs, &mut grid, &mut ws)?;
            inv_best = inv_best.min(t.elapsed().as_secs_f64());
            if base.real_input {
                // The real-input forward path rejects complex samples;
                // measure it on the real part of the synthesized grid.
                for z in grid.as_mut_slice() {
                    z.im = 0.0;
                }
            }
            let t = Instant::now();
            exec.forward_into(&grid, &mut back, &mut ws)?;
            fwd_best = fwd_best.min(t.elapsed().as_secs_f64());
        }
        let total = inv_best + fwd_best;
        let improves = match &best {
            None => true,
            Some((_, i, f)) => total < i + f,
        };
        if improves {
            best = Some((*cand, inv_best, fwd_best));
        }
    }
    let (winner, inv_seconds, fwd_seconds) =
        best.expect("candidate space is non-empty");
    Ok(SearchOutcome {
        winner,
        fwd_seconds,
        inv_seconds,
        shared_pool,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_is_the_documented_cross_product() {
        let space = candidate_space();
        assert_eq!(space.len(), 120);
        // Every axis value appears.
        assert!(space.iter().any(|c| c.algorithm == DwtAlgorithm::Clenshaw));
        assert!(space
            .iter()
            .any(|c| c.fft_engine == FftEngine::Radix2Baseline));
        assert!(space
            .iter()
            .any(|c| c.schedule == Schedule::Guided { min_chunk: 1 }));
        assert!(space
            .iter()
            .any(|c| c.strategy == PartitionStrategy::SigmaClustered));
        assert!(space.iter().any(|c| c.simd == SimdPolicy::Scalar));
        assert!(space.iter().any(|c| c.simd == SimdPolicy::Auto));
        // The Force* policies never enter the space: they can fail to
        // resolve on the running host, and Auto already covers the
        // best available ISA.
        assert!(space
            .iter()
            .all(|c| matches!(c.simd, SimdPolicy::Scalar | SimdPolicy::Auto)));
    }

    #[test]
    fn simulator_prefers_folded_split_radix() {
        // The coarse rates must rank the known-fast engines ahead of
        // the baselines, or the top-k pruning would discard the winner.
        let fast = Candidate {
            schedule: Schedule::Dynamic { chunk: 1 },
            strategy: PartitionStrategy::GeometricClustered,
            algorithm: DwtAlgorithm::MatVecFolded,
            fft_engine: FftEngine::SplitRadix,
            simd: SimdPolicy::Auto,
        };
        let slow = Candidate {
            fft_engine: FftEngine::Radix2Baseline,
            algorithm: DwtAlgorithm::MatVec,
            ..fast
        };
        assert!(simulated_seconds(16, &fast, 2) < simulated_seconds(16, &slow, 2));
    }

    #[test]
    fn search_returns_a_timed_winner_quickly() {
        let out = search(4, &ExecutorConfig::default(), Duration::from_millis(50)).unwrap();
        assert!(out.fwd_seconds.is_finite() && out.fwd_seconds > 0.0);
        assert!(out.inv_seconds.is_finite() && out.inv_seconds > 0.0);
        assert!(out.shared_pool.is_none(), "sequential search spawns no pool");
    }
}

//! The persistent wisdom store: measured planner choices keyed by
//! `(bandwidth, direction, threads)` and stamped with a
//! [`MachineFingerprint`](super::fingerprint::MachineFingerprint).
//!
//! On-disk format (`SO3WIS1`, line-oriented text — diffable, and the
//! parser is a dozen lines):
//!
//! ```text
//! SO3WIS1
//! fingerprint 9a3c0f21e77b4d55
//! entry b=16 dir=inv threads=4 schedule=dynamic:1 strategy=geometric \
//!       algorithm=matvec-folded fft=split-radix seconds=1.234000e-3 simd=auto
//! ```
//!
//! The `simd` and `mem` fields are optional on read (files written
//! before the SIMD dispatch axis / the memory-budget axis existed
//! default to `auto`), so old SO3WIS1 stores stay readable. `mem`
//! records the budget the winning time was measured under; it is
//! informational and never applied on a hit.
//!
//! Failure policy (the FFTW wisdom contract): a corrupt or
//! wrong-version file is a [`WisdomWarning`], never an error — lookups
//! report [`WisdomLookup::Fallback`] and the planner keeps its static
//! defaults. A fingerprint mismatch is *not* a warning: the file is
//! fine, it just belongs to another machine, so its entries are ignored
//! and the planner re-measures (the next `record` rewrites the file
//! under the current fingerprint).
//!
//! The in-memory entry map doubles as the in-process memoization layer:
//! the file is read at most once per store, and repeated `Measure`
//! builds of a known key never touch the disk or the timer again.

use std::collections::HashMap;
use std::fmt;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::config::{parse_algorithm, parse_fft_engine};
use crate::coordinator::{MemoryBudget, PartitionStrategy};
use crate::dwt::DwtAlgorithm;
use crate::fft::FftEngine;
use crate::pool::Schedule;
use crate::simd::SimdPolicy;
use crate::util::{cache_file, lock_unpoisoned};

use super::fingerprint::MachineFingerprint;
use super::WisdomWarning;

/// Transform direction a measurement applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TuneDirection {
    /// Analysis (FSOFT) direction.
    Forward,
    /// Synthesis (iFSOFT) direction.
    Inverse,
}

impl TuneDirection {
    /// Canonical name (round-trips through [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            TuneDirection::Forward => "fwd",
            TuneDirection::Inverse => "inv",
        }
    }

    /// Parse from a stored string (`forward` | `inverse`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fwd" => Some(TuneDirection::Forward),
            "inv" => Some(TuneDirection::Inverse),
            _ => None,
        }
    }
}

/// One wisdom slot: the measured-best knobs for a transform shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WisdomKey {
    /// Transform bandwidth B.
    pub bandwidth: usize,
    /// Transform direction the entry was tuned for.
    pub direction: TuneDirection,
    /// Worker-thread count the entry was tuned at.
    pub threads: usize,
}

/// The winning knob setting for a [`WisdomKey`], with its measured time.
#[derive(Debug, Clone, PartialEq)]
pub struct WisdomEntry {
    /// Loop-scheduling policy.
    pub schedule: Schedule,
    /// Order-domain partition strategy.
    pub strategy: PartitionStrategy,
    /// DWT algorithm choice.
    pub algorithm: DwtAlgorithm,
    /// 1-D FFT engine.
    pub fft_engine: FftEngine,
    /// SIMD dispatch policy the winning time was measured with.
    pub simd: SimdPolicy,
    /// Memory budget the winning time was measured under. Recorded for
    /// provenance (a streamed-mode time is not comparable to a
    /// precomputed-mode time); never applied on a hit.
    pub mem: MemoryBudget,
    /// Best measured wall time (seconds) for this key.
    pub seconds: f64,
}

/// Canonical config-file name of a DWT algorithm.
pub fn algorithm_name(a: DwtAlgorithm) -> &'static str {
    match a {
        DwtAlgorithm::MatVecFolded => "matvec-folded",
        DwtAlgorithm::MatVec => "matvec",
        DwtAlgorithm::Clenshaw => "clenshaw",
    }
}

/// Canonical config-file name of an FFT engine.
pub fn fft_engine_name(e: FftEngine) -> &'static str {
    match e {
        FftEngine::SplitRadix => "split-radix",
        FftEngine::Radix2Baseline => "radix2-baseline",
    }
}

impl WisdomEntry {
    /// One-line human description ("schedule=dynamic:1 strategy=… …").
    pub fn describe(&self) -> String {
        format!(
            "schedule={} strategy={} algorithm={} fft={} simd={} mem={} seconds={:.3e}",
            self.schedule.name(),
            self.strategy.name(),
            algorithm_name(self.algorithm),
            fft_engine_name(self.fft_engine),
            self.simd.name(),
            self.mem.name(),
            self.seconds
        )
    }
}

/// Result of a store lookup.
#[derive(Debug, Clone)]
pub enum WisdomLookup {
    /// A tuned entry for this key on this machine.
    Hit(WisdomEntry),
    /// Nothing stored — the caller should measure and [`WisdomStore::record`].
    Miss,
    /// The backing file is unusable; keep the Estimate defaults.
    Fallback(WisdomWarning),
}

/// Monotonic counters of one store (see [`WisdomStore::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct WisdomStats {
    /// Lookups answered from a stored entry.
    pub hits: u64,
    /// Lookups that found nothing (and triggered a measurement).
    pub misses: u64,
    /// Full measurement passes run against this store.
    pub measurements: u64,
}

struct StoreState {
    /// Whether the backing file has been read (at most once per store).
    loaded: bool,
    entries: HashMap<WisdomKey, WisdomEntry>,
    /// Set when the backing file is unusable — every lookup then falls
    /// back until the process restarts (we never overwrite a file we
    /// could not parse: it may be the user's data from a newer version).
    warning: Option<WisdomWarning>,
}

/// See the [module docs](self). Shareable (`Arc`) across builders,
/// services, and caller threads.
pub struct WisdomStore {
    /// Backing file; `None` = purely in-memory (tests, benches).
    path: Option<PathBuf>,
    state: Mutex<StoreState>,
    hits: AtomicU64,
    misses: AtomicU64,
    measurements: AtomicU64,
    /// One warning line per store, not one per build.
    warned: AtomicBool,
}

impl WisdomStore {
    /// A store backed by `path` (read lazily, written on `record`).
    pub fn open(path: impl Into<PathBuf>) -> Arc<Self> {
        Arc::new(Self::new(Some(path.into())))
    }

    /// A store with no backing file — entries live for the process only.
    pub fn in_memory() -> Arc<Self> {
        Arc::new(Self::new(None))
    }

    /// The process-wide default store, backed by
    /// `util::cache_dir()/wisdom.so3wis`.
    pub fn global() -> Arc<Self> {
        static GLOBAL: OnceLock<Arc<WisdomStore>> = OnceLock::new();
        Arc::clone(GLOBAL.get_or_init(|| WisdomStore::open(cache_file("wisdom.so3wis"))))
    }

    fn new(path: Option<PathBuf>) -> Self {
        Self {
            path,
            state: Mutex::new(StoreState {
                loaded: false,
                entries: HashMap::new(),
                warning: None,
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            measurements: AtomicU64::new(0),
            warned: AtomicBool::new(false),
        }
    }

    /// The backing file, if any.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Look up the tuned entry for `key`, loading the backing file on
    /// first use. Bumps the hit/miss counters.
    pub fn lookup(&self, key: WisdomKey) -> WisdomLookup {
        let mut state = lock_unpoisoned(&self.state);
        self.ensure_loaded(&mut state);
        if let Some(w) = &state.warning {
            return WisdomLookup::Fallback(w.clone());
        }
        match state.entries.get(&key) {
            Some(e) => {
                // ordering: Relaxed — standalone statistic counter; the
                // entry itself is read under the state mutex above.
                self.hits.fetch_add(1, Ordering::Relaxed);
                WisdomLookup::Hit(e.clone())
            }
            None => {
                // ordering: Relaxed — standalone statistic counter.
                self.misses.fetch_add(1, Ordering::Relaxed);
                WisdomLookup::Miss
            }
        }
    }

    /// Store a measured entry (keeping the better of two measurements
    /// for the same key) and persist best-effort. A failed write keeps
    /// the in-memory entry — persistence is an optimization, never a
    /// correctness requirement.
    pub fn record(&self, key: WisdomKey, entry: WisdomEntry) {
        let mut state = lock_unpoisoned(&self.state);
        self.ensure_loaded(&mut state);
        if state.warning.is_some() {
            // Never rewrite a file we could not parse.
            return;
        }
        state.entries.insert(key, entry);
        if let Err(e) = self.persist(&state) {
            // ordering: Relaxed — once-flag for a log line; duplicate
            // warnings on a lost race would be cosmetic, and the swap
            // itself is already atomic.
            if !self.warned.swap(true, Ordering::Relaxed) {
                eprintln!(
                    "so3ft wisdom: could not persist {:?}: {e} (entries stay in-memory)",
                    self.path
                );
            }
        }
    }

    /// Count one full measurement pass (for tests and `wisdom train`).
    pub fn note_measurement(&self) {
        // ordering: Relaxed — standalone statistic counter.
        self.measurements.fetch_add(1, Ordering::Relaxed);
    }

    /// Hit/miss/measurement counters for this store.
    pub fn stats(&self) -> WisdomStats {
        WisdomStats {
            // ordering: Relaxed — statistics snapshot; the three
            // counters are independent tallies, not a consistent cut.
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            measurements: self.measurements.load(Ordering::Relaxed),
        }
    }

    /// All stored entries, sorted by key (for `wisdom show`).
    pub fn entries(&self) -> Vec<(WisdomKey, WisdomEntry)> {
        let mut state = lock_unpoisoned(&self.state);
        self.ensure_loaded(&mut state);
        let mut v: Vec<_> = state
            .entries
            .iter()
            .map(|(k, e)| (*k, e.clone()))
            .collect();
        v.sort_by_key(|(k, _)| (k.bandwidth, k.direction.name(), k.threads));
        v
    }

    /// Drop every entry and delete the backing file (for `wisdom clear`).
    /// Also clears a fallback warning: the unusable file is gone.
    pub fn clear(&self) {
        let mut state = lock_unpoisoned(&self.state);
        state.entries.clear();
        state.warning = None;
        state.loaded = true;
        if let Some(p) = &self.path {
            let _ = std::fs::remove_file(p);
        }
    }

    /// Emit `warning` to stderr once per store lifetime.
    pub(crate) fn warn_once(&self, warning: &WisdomWarning) {
        // ordering: Relaxed — once-flag for a log line (see `record`).
        if !self.warned.swap(true, Ordering::Relaxed) {
            eprintln!("so3ft wisdom: {warning}; falling back to Estimate defaults");
        }
    }

    fn ensure_loaded(&self, state: &mut StoreState) {
        if state.loaded {
            return;
        }
        state.loaded = true;
        // Fault site: an injected I/O failure must degrade exactly like a
        // real unreadable store — a one-shot warning and Estimate-mode
        // fallback, never an error on the transform path.
        if let Some(action) = crate::faults::fire(crate::faults::WISDOM_STORE) {
            if let Err(e) = action.apply(crate::faults::WISDOM_STORE) {
                state.warning = Some(WisdomWarning::Io {
                    path: self.path.clone().unwrap_or_default(),
                    detail: e.to_string(),
                });
                return;
            }
        }
        let Some(path) = &self.path else { return };
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return,
            Err(e) => {
                state.warning = Some(WisdomWarning::Io {
                    path: path.clone(),
                    detail: e.to_string(),
                });
                return;
            }
        };
        match parse_file(&text, path) {
            Ok(Some(entries)) => state.entries = entries,
            // Valid file, foreign fingerprint: ignore entries, re-measure.
            Ok(None) => {}
            Err(w) => state.warning = Some(w),
        }
    }

    fn persist(&self, state: &StoreState) -> std::io::Result<()> {
        let Some(path) = &self.path else {
            return Ok(());
        };
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut keys: Vec<_> = state.entries.keys().copied().collect();
        keys.sort_by_key(|k| (k.bandwidth, k.direction.name(), k.threads));
        let mut out = Vec::with_capacity(keys.len() + 2);
        out.push("SO3WIS1".to_string());
        out.push(format!(
            "fingerprint {:016x}",
            MachineFingerprint::current().digest()
        ));
        for k in keys {
            let e = &state.entries[&k];
            out.push(format!(
                "entry b={} dir={} threads={} schedule={} strategy={} algorithm={} \
                 fft={} seconds={:.6e} simd={} mem={}",
                k.bandwidth,
                k.direction.name(),
                k.threads,
                e.schedule.name(),
                e.strategy.name(),
                algorithm_name(e.algorithm),
                fft_engine_name(e.fft_engine),
                e.seconds,
                e.simd.name(),
                e.mem.name()
            ));
        }
        // Write-then-rename so a crash mid-write never corrupts the store.
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)?;
            for line in &out {
                writeln!(f, "{line}")?;
            }
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)
    }
}

impl fmt::Debug for WisdomStore {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WisdomStore")
            .field("path", &self.path)
            .field("stats", &self.stats())
            .finish()
    }
}

/// Parse an `SO3WIS1` file. `Ok(None)` = foreign fingerprint (valid
/// file, ignore entries); `Err` = version mismatch or corruption.
fn parse_file(
    text: &str,
    path: &Path,
) -> std::result::Result<Option<HashMap<WisdomKey, WisdomEntry>>, WisdomWarning> {
    let corrupt = |detail: String| WisdomWarning::CorruptStore {
        path: path.to_path_buf(),
        detail,
    };
    let mut lines = text.lines().filter(|l| {
        let t = l.trim();
        !t.is_empty() && !t.starts_with('#')
    });
    match lines.next() {
        Some("SO3WIS1") => {}
        Some(v) if v.starts_with("SO3WIS") => {
            return Err(WisdomWarning::VersionMismatch {
                path: path.to_path_buf(),
                found: v.to_string(),
            })
        }
        other => {
            return Err(corrupt(format!(
                "expected SO3WIS1 header, got {other:?}"
            )))
        }
    }
    let fp_line = lines
        .next()
        .ok_or_else(|| corrupt("missing fingerprint line".into()))?;
    let digest = fp_line
        .strip_prefix("fingerprint ")
        .and_then(|h| u64::from_str_radix(h.trim(), 16).ok())
        .ok_or_else(|| corrupt(format!("bad fingerprint line {fp_line:?}")))?;
    let foreign = digest != MachineFingerprint::current().digest();
    let mut entries = HashMap::new();
    for line in lines {
        let body = line
            .trim()
            .strip_prefix("entry ")
            .ok_or_else(|| corrupt(format!("unexpected line {line:?}")))?;
        let mut fields: HashMap<&str, &str> = HashMap::new();
        for tok in body.split_whitespace() {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| corrupt(format!("bad field {tok:?}")))?;
            fields.insert(k, v);
        }
        let get = |name: &str| {
            fields
                .get(name)
                .copied()
                .ok_or_else(|| corrupt(format!("entry missing {name:?}: {line:?}")))
        };
        let bad = |name: &str, v: &str| corrupt(format!("bad {name} {v:?} in {line:?}"));
        let b_s = get("b")?;
        let dir_s = get("dir")?;
        let threads_s = get("threads")?;
        let sched_s = get("schedule")?;
        let strat_s = get("strategy")?;
        let algo_s = get("algorithm")?;
        let fft_s = get("fft")?;
        let secs_s = get("seconds")?;
        // Optional: absent in stores written before the SIMD axis.
        let simd = match fields.get("simd") {
            Some(s) => SimdPolicy::parse(s).map_err(|_| bad("simd", s))?,
            None => SimdPolicy::Auto,
        };
        // Optional: absent in stores written before the memory axis.
        let mem = match fields.get("mem") {
            Some(s) => MemoryBudget::parse(s).ok_or_else(|| bad("mem", s))?,
            None => MemoryBudget::Auto,
        };
        let key = WisdomKey {
            bandwidth: b_s.parse().map_err(|_| bad("b", b_s))?,
            direction: TuneDirection::parse(dir_s).ok_or_else(|| bad("dir", dir_s))?,
            threads: threads_s.parse().map_err(|_| bad("threads", threads_s))?,
        };
        let entry = WisdomEntry {
            schedule: Schedule::parse(sched_s).ok_or_else(|| bad("schedule", sched_s))?,
            strategy: PartitionStrategy::parse(strat_s)
                .ok_or_else(|| bad("strategy", strat_s))?,
            algorithm: parse_algorithm(algo_s).map_err(|_| bad("algorithm", algo_s))?,
            fft_engine: parse_fft_engine(fft_s).map_err(|_| bad("fft", fft_s))?,
            simd,
            mem,
            seconds: secs_s
                .parse::<f64>()
                .ok()
                .filter(|s| s.is_finite() && *s >= 0.0)
                .ok_or_else(|| bad("seconds", secs_s))?,
        };
        entries.insert(key, entry);
    }
    Ok(if foreign { None } else { Some(entries) })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(b: usize) -> WisdomKey {
        WisdomKey {
            bandwidth: b,
            direction: TuneDirection::Inverse,
            threads: 1,
        }
    }

    fn entry(seconds: f64) -> WisdomEntry {
        WisdomEntry {
            schedule: Schedule::Dynamic { chunk: 4 },
            strategy: PartitionStrategy::SigmaClustered,
            algorithm: DwtAlgorithm::MatVec,
            fft_engine: FftEngine::Radix2Baseline,
            simd: SimdPolicy::Scalar,
            mem: MemoryBudget::Auto,
            seconds,
        }
    }

    fn temp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "so3ft-wisdom-store-{tag}-{}.so3wis",
            std::process::id()
        ))
    }

    #[test]
    fn in_memory_miss_then_hit() {
        let store = WisdomStore::in_memory();
        assert!(matches!(store.lookup(key(8)), WisdomLookup::Miss));
        store.record(key(8), entry(1e-3));
        match store.lookup(key(8)) {
            WisdomLookup::Hit(e) => assert_eq!(e, entry(1e-3)),
            other => panic!("expected hit, got {other:?}"),
        }
        let s = store.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn disk_roundtrip_and_reopen() {
        let path = temp_path("roundtrip");
        let _ = std::fs::remove_file(&path);
        let store = WisdomStore::open(&path);
        store.record(key(8), entry(2e-3));
        store.record(key(16), entry(5e-3));
        drop(store);
        let reopened = WisdomStore::open(&path);
        match reopened.lookup(key(16)) {
            WisdomLookup::Hit(e) => assert_eq!(e, entry(5e-3)),
            other => panic!("expected hit after reopen, got {other:?}"),
        }
        assert_eq!(reopened.entries().len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn wrong_version_and_garbage_fall_back() {
        let path = temp_path("badversion");
        std::fs::write(&path, "SO3WIS9\nfingerprint 0\n").unwrap();
        let store = WisdomStore::open(&path);
        assert!(matches!(
            store.lookup(key(8)),
            WisdomLookup::Fallback(WisdomWarning::VersionMismatch { .. })
        ));
        // A fallback store refuses to overwrite the file.
        store.record(key(8), entry(1e-3));
        assert!(std::fs::read_to_string(&path).unwrap().starts_with("SO3WIS9"));
        let _ = std::fs::remove_file(&path);

        let path = temp_path("garbage");
        std::fs::write(&path, "not a wisdom file at all\n").unwrap();
        let store = WisdomStore::open(&path);
        assert!(matches!(
            store.lookup(key(8)),
            WisdomLookup::Fallback(WisdomWarning::CorruptStore { .. })
        ));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn foreign_fingerprint_ignores_entries_without_warning() {
        let path = temp_path("foreign");
        let _ = std::fs::remove_file(&path);
        let store = WisdomStore::open(&path);
        store.record(key(8), entry(1e-3));
        drop(store);
        // Rewrite the header with a zeroed fingerprint.
        let text = std::fs::read_to_string(&path).unwrap();
        let patched: Vec<String> = text
            .lines()
            .map(|l| {
                if l.starts_with("fingerprint ") {
                    "fingerprint 0000000000000000".to_string()
                } else {
                    l.to_string()
                }
            })
            .collect();
        std::fs::write(&path, patched.join("\n")).unwrap();
        let reopened = WisdomStore::open(&path);
        // Not a fallback — a clean miss, prompting re-measurement.
        assert!(matches!(reopened.lookup(key(8)), WisdomLookup::Miss));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn pre_simd_entries_parse_with_auto_default() {
        let path = temp_path("presimd");
        let _ = std::fs::remove_file(&path);
        // Write a store under the current fingerprint, then strip the
        // simd= fields to imitate a file from a pre-SIMD release.
        let store = WisdomStore::open(&path);
        store.record(key(8), entry(1e-3));
        drop(store);
        let text = std::fs::read_to_string(&path).unwrap();
        let patched: Vec<String> = text
            .lines()
            .map(|l| {
                l.split_whitespace()
                    .filter(|tok| !tok.starts_with("simd="))
                    .collect::<Vec<_>>()
                    .join(" ")
            })
            .collect();
        std::fs::write(&path, patched.join("\n")).unwrap();
        let reopened = WisdomStore::open(&path);
        match reopened.lookup(key(8)) {
            WisdomLookup::Hit(e) => assert_eq!(e.simd, SimdPolicy::Auto),
            other => panic!("expected hit on pre-simd file, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn pre_mem_entries_parse_with_auto_default() {
        let path = temp_path("premem");
        let _ = std::fs::remove_file(&path);
        // Strip the mem= fields to imitate a file from a pre-0.9 release.
        let store = WisdomStore::open(&path);
        store.record(
            key(8),
            WisdomEntry {
                mem: MemoryBudget::Bytes(1 << 30),
                ..entry(1e-3)
            },
        );
        drop(store);
        let text = std::fs::read_to_string(&path).unwrap();
        let patched: Vec<String> = text
            .lines()
            .map(|l| {
                l.split_whitespace()
                    .filter(|tok| !tok.starts_with("mem="))
                    .collect::<Vec<_>>()
                    .join(" ")
            })
            .collect();
        std::fs::write(&path, patched.join("\n")).unwrap();
        let reopened = WisdomStore::open(&path);
        match reopened.lookup(key(8)) {
            WisdomLookup::Hit(e) => assert_eq!(e.mem, MemoryBudget::Auto),
            other => panic!("expected hit on pre-mem file, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn clear_removes_file_and_entries() {
        let path = temp_path("clear");
        let store = WisdomStore::open(&path);
        store.record(key(8), entry(1e-3));
        assert!(path.exists());
        store.clear();
        assert!(!path.exists());
        assert!(matches!(store.lookup(key(8)), WisdomLookup::Miss));
    }
}

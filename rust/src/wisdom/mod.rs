//! Measured auto-tuning (the FFTW "wisdom" idiom) for
//! [`crate::transform::So3Plan`] building.
//!
//! [`crate::transform::So3PlanBuilder::rigor`] selects between:
//!
//! * [`PlanRigor::Estimate`] (default) — today's static defaults,
//!   bit-identical and zero-cost; and
//! * [`PlanRigor::Measure`] — a build-time search over the tunable knob
//!   space (DWT algorithm × FFT engine × schedule × partition
//!   strategy × SIMD policy), pruned by the `simulator/` cost model and wall-clocked
//!   on the plan's own worker pool ([`search`]), with the winner
//!   persisted in a machine-fingerprinted [`store::WisdomStore`] so
//!   the measurement runs once per `(bandwidth, direction, threads,
//!   machine)` — ever.
//!
//! Wisdom only ever *selects among* the crate's parity-tested engines;
//! it never changes what any engine computes. A Measure-built plan is
//! bit-identical to an Estimate plan configured with the same winning
//! knobs (pinned by `rust/tests/wisdom.rs`).
//!
//! Every degraded path is a typed [`WisdomWarning`] and a fallback to
//! Estimate behavior — a corrupt wisdom file can slow a build down, but
//! it can never fail one.

pub mod fingerprint;
pub mod search;
pub mod store;

pub use fingerprint::MachineFingerprint;
pub use search::{candidate_space, Candidate};
pub use store::{
    TuneDirection, WisdomEntry, WisdomKey, WisdomLookup, WisdomStats, WisdomStore,
};

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::ExecutorConfig;
use crate::pool::PoolSpec;

/// How much effort `So3PlanBuilder::build` spends choosing a plan
/// configuration (names follow FFTW's planner rigor levels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlanRigor {
    /// Keep the builder's static configuration untouched (the default;
    /// zero build-time cost).
    #[default]
    Estimate,
    /// Search the knob space at build time, reusing persisted wisdom
    /// when available. Explicit builder settings for the searched axes
    /// are treated as a starting point and may be overridden by the
    /// measured winner.
    Measure,
}

impl PlanRigor {
    /// Parse from a CLI/config string (`estimate` | `measure` | `exhaustive`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "estimate" => Some(PlanRigor::Estimate),
            "measure" => Some(PlanRigor::Measure),
            _ => None,
        }
    }

    /// Canonical name (round-trips through [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            PlanRigor::Estimate => "estimate",
            PlanRigor::Measure => "measure",
        }
    }
}

/// Why a `Measure` build kept the Estimate defaults. Warnings, not
/// errors: plan building succeeds regardless.
#[derive(Debug, Clone, PartialEq)]
pub enum WisdomWarning {
    /// The wisdom file exists but is not parseable.
    CorruptStore {
        /// The store file that failed to parse.
        path: PathBuf,
        /// Parser detail.
        detail: String,
    },
    /// The wisdom file carries a different `SO3WIS*` format version.
    VersionMismatch {
        /// The store file that was ignored.
        path: PathBuf,
        /// Version string found in the file.
        found: String,
    },
    /// The wisdom file could not be read (permissions, I/O).
    Io {
        /// The store path that could not be read or written.
        path: PathBuf,
        /// OS error detail.
        detail: String,
    },
    /// Measure was requested on a plan with a DWT offload attached —
    /// the search times the CPU engines, which would mis-tune the
    /// offloaded plan.
    OffloadAttached,
    /// The measurement pass itself failed (e.g. pool spawn failure).
    SearchFailed {
        /// Why the measured search was abandoned.
        detail: String,
    },
}

impl std::fmt::Display for WisdomWarning {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WisdomWarning::CorruptStore { path, detail } => {
                write!(f, "corrupt wisdom store {path:?}: {detail}")
            }
            WisdomWarning::VersionMismatch { path, found } => write!(
                f,
                "wisdom store {path:?} has format {found:?} (this build reads SO3WIS1)"
            ),
            WisdomWarning::Io { path, detail } => {
                write!(f, "cannot read wisdom store {path:?}: {detail}")
            }
            WisdomWarning::OffloadAttached => write!(
                f,
                "PlanRigor::Measure ignored: a DWT offload is attached and the \
                 search times the CPU engines"
            ),
            WisdomWarning::SearchFailed { detail } => {
                write!(f, "wisdom search failed: {detail}")
            }
        }
    }
}

/// Where a `Measure` build's configuration came from.
#[derive(Debug, Clone, PartialEq)]
pub enum WisdomSource {
    /// Served from the store (file or in-process memoization).
    CacheHit,
    /// Measured in this build and recorded.
    Measured,
    /// Estimate defaults kept; the warning says why.
    Fallback(WisdomWarning),
}

/// The knobs a `Measure` build settled on, with their measured times.
#[derive(Debug, Clone, PartialEq)]
pub struct TunedChoice {
    /// Loop-scheduling policy.
    pub schedule: crate::pool::Schedule,
    /// Order-domain partition strategy.
    pub strategy: crate::coordinator::PartitionStrategy,
    /// DWT algorithm choice.
    pub algorithm: crate::dwt::DwtAlgorithm,
    /// 1-D FFT engine.
    pub fft_engine: crate::fft::FftEngine,
    /// SIMD dispatch policy.
    pub simd: crate::simd::SimdPolicy,
    /// Measured forward-transform seconds (0 when estimated).
    pub fwd_seconds: f64,
    /// Measured inverse-transform seconds (0 when estimated).
    pub inv_seconds: f64,
}

/// What `PlanRigor::Measure` did during a build (see
/// [`crate::transform::So3Plan::wisdom`]).
#[derive(Debug, Clone, PartialEq)]
pub struct WisdomOutcome {
    /// Where the winning configuration came from.
    pub source: WisdomSource,
    /// The applied knobs; `None` on fallback.
    pub choice: Option<TunedChoice>,
    /// Wall time this build spent in wisdom (lookup + search).
    pub search_seconds: f64,
}

fn apply(config: &mut ExecutorConfig, choice: &TunedChoice) {
    config.schedule = choice.schedule;
    config.strategy = choice.strategy;
    config.algorithm = choice.algorithm;
    config.fft_engine = choice.fft_engine;
    config.simd = choice.simd;
}

/// Run the `Measure` path for one build: look `config`'s shape up in
/// `store`, measuring (and recording, both directions) on a miss, and
/// mutate `config` to the winning knobs. Degraded stores or failed
/// searches leave `config` untouched and report a
/// [`WisdomSource::Fallback`].
pub(crate) fn tune(
    store: &Arc<WisdomStore>,
    b: usize,
    config: &mut ExecutorConfig,
    budget: Duration,
) -> WisdomOutcome {
    let started = Instant::now();
    let key = WisdomKey {
        bandwidth: b,
        direction: TuneDirection::Inverse,
        threads: config.threads,
    };
    match store.lookup(key) {
        WisdomLookup::Hit(entry) => {
            let choice = TunedChoice {
                schedule: entry.schedule,
                strategy: entry.strategy,
                algorithm: entry.algorithm,
                fft_engine: entry.fft_engine,
                simd: entry.simd,
                // Stored "seconds" is the per-direction best at record
                // time; the forward slot shares the file.
                inv_seconds: entry.seconds,
                fwd_seconds: match store.lookup(WisdomKey {
                    direction: TuneDirection::Forward,
                    ..key
                }) {
                    WisdomLookup::Hit(fwd) => fwd.seconds,
                    _ => entry.seconds,
                },
            };
            apply(config, &choice);
            WisdomOutcome {
                source: WisdomSource::CacheHit,
                choice: Some(choice),
                search_seconds: started.elapsed().as_secs_f64(),
            }
        }
        WisdomLookup::Fallback(warning) => {
            store.warn_once(&warning);
            WisdomOutcome {
                source: WisdomSource::Fallback(warning),
                choice: None,
                search_seconds: started.elapsed().as_secs_f64(),
            }
        }
        WisdomLookup::Miss => match search::search(b, config, budget) {
            Ok(out) => {
                store.note_measurement();
                let base_entry = WisdomEntry {
                    schedule: out.winner.schedule,
                    strategy: out.winner.strategy,
                    algorithm: out.winner.algorithm,
                    fft_engine: out.winner.fft_engine,
                    simd: out.winner.simd,
                    // Provenance only: records the budget the winning
                    // time was measured under (never applied on a hit).
                    mem: config.memory,
                    seconds: out.inv_seconds,
                };
                store.record(key, base_entry.clone());
                store.record(
                    WisdomKey {
                        direction: TuneDirection::Forward,
                        ..key
                    },
                    WisdomEntry {
                        seconds: out.fwd_seconds,
                        ..base_entry
                    },
                );
                let choice = TunedChoice {
                    schedule: out.winner.schedule,
                    strategy: out.winner.strategy,
                    algorithm: out.winner.algorithm,
                    fft_engine: out.winner.fft_engine,
                    simd: out.winner.simd,
                    fwd_seconds: out.fwd_seconds,
                    inv_seconds: out.inv_seconds,
                };
                apply(config, &choice);
                // The search already spun up the measurement pool for
                // owned-pool configs; the plan reuses it instead of
                // spawning a second one.
                if let Some(pool) = out.shared_pool {
                    if matches!(config.pool, PoolSpec::Owned) {
                        config.pool = PoolSpec::Shared(pool);
                    }
                }
                WisdomOutcome {
                    source: WisdomSource::Measured,
                    choice: Some(choice),
                    search_seconds: started.elapsed().as_secs_f64(),
                }
            }
            Err(e) => {
                let warning = WisdomWarning::SearchFailed {
                    detail: e.to_string(),
                };
                store.warn_once(&warning);
                WisdomOutcome {
                    source: WisdomSource::Fallback(warning),
                    choice: None,
                    search_seconds: started.elapsed().as_secs_f64(),
                }
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rigor_parses_and_names_roundtrip() {
        assert_eq!(PlanRigor::parse("estimate"), Some(PlanRigor::Estimate));
        assert_eq!(PlanRigor::parse("measure"), Some(PlanRigor::Measure));
        assert_eq!(PlanRigor::parse("exhaustive"), None);
        for r in [PlanRigor::Estimate, PlanRigor::Measure] {
            assert_eq!(PlanRigor::parse(r.name()), Some(r));
        }
        assert_eq!(PlanRigor::default(), PlanRigor::Estimate);
    }

    #[test]
    fn tune_measures_once_then_hits_memoization() {
        let store = WisdomStore::in_memory();
        let mut config = ExecutorConfig::default();
        let out = tune(&store, 4, &mut config, Duration::from_millis(30));
        assert_eq!(out.source, WisdomSource::Measured);
        assert!(out.choice.is_some());
        let mut config2 = ExecutorConfig::default();
        let out2 = tune(&store, 4, &mut config2, Duration::from_millis(30));
        assert_eq!(out2.source, WisdomSource::CacheHit);
        assert_eq!(store.stats().measurements, 1);
        // Both builds settle on the same knobs.
        assert_eq!(config.schedule, config2.schedule);
        assert_eq!(config.algorithm, config2.algorithm);
        assert_eq!(config.fft_engine, config2.fft_engine);
        assert_eq!(config.strategy, config2.strategy);
        assert_eq!(config.simd, config2.simd);
    }

    #[test]
    fn warning_display_is_informative() {
        let w = WisdomWarning::VersionMismatch {
            path: PathBuf::from("/tmp/w.so3wis"),
            found: "SO3WIS9".into(),
        };
        let s = w.to_string();
        assert!(s.contains("SO3WIS9") && s.contains("SO3WIS1"), "{s}");
    }
}

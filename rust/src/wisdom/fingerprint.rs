//! Machine fingerprint for wisdom entries.
//!
//! Measured timings are only meaningful on the machine that produced
//! them, so every wisdom file is stamped with a digest of the facts
//! that shape the measurement: core count, cache-line size, target
//! arch/OS, the detected SIMD ISA (the vector kernels change which
//! engine wins), and the crate version (kernels change between
//! releases). A digest mismatch on load silently invalidates the stored
//! entries — the planner re-measures rather than trusting stale
//! timings.

use std::fmt;

/// The machine facts a wisdom measurement depends on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MachineFingerprint {
    /// Available hardware parallelism.
    pub cores: usize,
    /// Assumed cache-line size in bytes (per-arch constant; `std` has no
    /// portable query).
    pub cache_line: usize,
    /// `std::env::consts::ARCH`.
    pub arch: &'static str,
    /// `std::env::consts::OS`.
    pub os: &'static str,
    /// The process-detected SIMD ISA ([`crate::simd::detected_isa`]) —
    /// timings measured with AVX2 kernels don't transfer to a
    /// scalar-only host (or to a `SO3FT_FORCE_SCALAR=1` run).
    pub simd: &'static str,
    /// `CARGO_PKG_VERSION` at build time.
    pub crate_version: &'static str,
}

impl MachineFingerprint {
    /// The fingerprint of the running process.
    pub fn current() -> Self {
        Self {
            cores: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
            cache_line: if cfg!(target_arch = "aarch64") { 128 } else { 64 },
            arch: std::env::consts::ARCH,
            os: std::env::consts::OS,
            simd: crate::simd::detected_isa().name(),
            crate_version: env!("CARGO_PKG_VERSION"),
        }
    }

    /// FNV-1a hash of the canonical display form — the value stored in
    /// the wisdom file header.
    pub fn digest(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        for byte in self.to_string().bytes() {
            h ^= u64::from(byte);
            h = h.wrapping_mul(FNV_PRIME);
        }
        h
    }
}

impl fmt::Display for MachineFingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "cores={} cache_line={} arch={} os={} simd={} crate={}",
            self.cores, self.cache_line, self.arch, self.os, self.simd, self.crate_version
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn current_is_stable_within_a_process() {
        let a = MachineFingerprint::current();
        let b = MachineFingerprint::current();
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        assert!(a.cores >= 1);
    }

    #[test]
    fn digest_tracks_every_field() {
        let base = MachineFingerprint::current();
        let mut other = base.clone();
        other.cores = base.cores + 1;
        assert_ne!(base.digest(), other.digest());
        let mut other = base.clone();
        other.cache_line = base.cache_line * 2;
        assert_ne!(base.digest(), other.digest());
        let mut other = base.clone();
        other.simd = if base.simd == "scalar" { "avx2" } else { "scalar" };
        assert_ne!(base.digest(), other.digest());
    }

    #[test]
    fn display_is_the_documented_form() {
        let fp = MachineFingerprint {
            cores: 4,
            cache_line: 64,
            arch: "x86_64",
            os: "linux",
            simd: "avx2",
            crate_version: "0.8.0",
        };
        assert_eq!(
            fp.to_string(),
            "cores=4 cache_line=64 arch=x86_64 os=linux simd=avx2 crate=0.8.0"
        );
    }
}

//! The Kostelec–Rockmore sampling grid on SO(3) and the grid-value
//! container used by the transforms.
//!
//! For bandwidth B the grid has (2B)³ nodes with angles
//! `α_i = iπ/B`, `β_j = (2j+1)π/(4B)`, `γ_k = kπ/B` (paper Eq. 5).
//!
//! Layout: **β-major, row-major (j, i, k)** — one β-slice is a contiguous
//! `2B × 2B` matrix over (α, γ), which is exactly what the 2-D FFT stage
//! wants, and each slice can be handed to a different worker.

use crate::error::{Error, Result};
use crate::fft::Complex64;
use crate::so3::rotation::EulerZyz;

/// Grid angles for bandwidth B.
#[derive(Debug, Clone)]
pub struct GridAngles {
    /// Bandwidth B of the grid.
    pub b: usize,
    /// The 2B equispaced α samples.
    pub alphas: Vec<f64>,
    /// The 2B Chebyshev β samples.
    pub betas: Vec<f64>,
    /// The 2B equispaced γ samples.
    pub gammas: Vec<f64>,
}

impl GridAngles {
    /// Sampling angles for bandwidth `b` (paper Eq. 9).
    pub fn new(b: usize) -> Result<Self> {
        if b == 0 {
            return Err(Error::InvalidBandwidth(b));
        }
        let n = 2 * b;
        let pi = std::f64::consts::PI;
        let alphas: Vec<f64> = (0..n).map(|i| i as f64 * pi / b as f64).collect();
        let betas: Vec<f64> = (0..n)
            .map(|j| (2 * j + 1) as f64 * pi / (4.0 * b as f64))
            .collect();
        let gammas = alphas.clone();
        Ok(Self {
            b,
            alphas,
            betas,
            gammas,
        })
    }

    /// Euler angles of node (i, j, k).
    pub fn euler(&self, i: usize, j: usize, k: usize) -> EulerZyz {
        EulerZyz::new(self.alphas[i], self.betas[j], self.gammas[k])
    }
}

/// Sampled function values on the (2B)³ grid, layout `[j][i][k]`.
#[derive(Debug, Clone, PartialEq)]
pub struct So3Grid {
    b: usize,
    data: Vec<Complex64>,
}

impl So3Grid {
    /// Zero-filled grid.
    pub fn zeros(b: usize) -> Result<Self> {
        if b == 0 {
            return Err(Error::InvalidBandwidth(b));
        }
        let n = 2 * b;
        Ok(Self {
            b,
            data: vec![Complex64::zero(); n * n * n],
        })
    }

    /// Wrap existing values (must have length (2B)³, layout [j][i][k]).
    pub fn from_vec(b: usize, data: Vec<Complex64>) -> Result<Self> {
        let n = 2 * b;
        if data.len() != n * n * n {
            return Err(Error::shape(n * n * n, data.len(), "So3Grid::from_vec"));
        }
        Ok(Self { b, data })
    }

    /// Bandwidth B of this grid.
    #[inline]
    pub fn bandwidth(&self) -> usize {
        self.b
    }

    /// Grid edge 2B.
    #[inline]
    pub fn edge(&self) -> usize {
        2 * self.b
    }

    /// Total number of samples (`(2B)³`).
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the grid is empty.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Flat index of sample `(i, j, k)` = (α, β, γ).
    #[inline]
    pub fn flat_index(&self, i: usize, j: usize, k: usize) -> usize {
        let n = self.edge();
        debug_assert!(i < n && j < n && k < n);
        (j * n + i) * n + k
    }

    /// Value at node (α_i, β_j, γ_k).
    #[inline]
    pub fn get(&self, i: usize, j: usize, k: usize) -> Complex64 {
        self.data[self.flat_index(i, j, k)]
    }

    /// Store sample `(i, j, k)`.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, k: usize, v: Complex64) {
        let idx = self.flat_index(i, j, k);
        self.data[idx] = v;
    }

    /// The contiguous β-slice j as a 2B×2B row-major matrix over (i, k).
    pub fn slice(&self, j: usize) -> &[Complex64] {
        let n = self.edge();
        &self.data[j * n * n..(j + 1) * n * n]
    }

    /// Mutable α×γ plane at β index `j`.
    pub fn slice_mut(&mut self, j: usize) -> &mut [Complex64] {
        let n = self.edge();
        &mut self.data[j * n * n..(j + 1) * n * n]
    }

    /// Flat sample storage.
    pub fn as_slice(&self) -> &[Complex64] {
        &self.data
    }

    /// Flat mutable sample storage.
    pub fn as_mut_slice(&mut self) -> &mut [Complex64] {
        &mut self.data
    }

    /// The flat storage, consuming `self`.
    pub fn into_vec(self) -> Vec<Complex64> {
        self.data
    }

    /// Max |difference| against another grid (same bandwidth required).
    pub fn max_abs_error(&self, other: &So3Grid) -> f64 {
        assert_eq!(self.b, other.b, "bandwidth mismatch");
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (*a - *b).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn angles_match_paper_formulas() {
        let g = GridAngles::new(4).unwrap();
        let pi = std::f64::consts::PI;
        assert_eq!(g.alphas.len(), 8);
        assert!((g.alphas[3] - 3.0 * pi / 4.0).abs() < 1e-15);
        assert!((g.betas[0] - pi / 16.0).abs() < 1e-15);
        assert!((g.betas[7] - 15.0 * pi / 16.0).abs() < 1e-15);
        assert_eq!(g.alphas, g.gammas);
        // β stays strictly inside (0, π): the log-domain Wigner seeds
        // depend on it.
        for &bj in &g.betas {
            assert!(bj > 0.0 && bj < pi);
        }
    }

    #[test]
    fn beta_nodes_are_reflection_symmetric() {
        // π - β_j = β_{2B-1-j}: the property the symmetry clustering uses.
        for b in [1usize, 3, 8, 16] {
            let g = GridAngles::new(b).unwrap();
            let n = 2 * b;
            for j in 0..n {
                let refl = std::f64::consts::PI - g.betas[j];
                assert!(
                    (refl - g.betas[n - 1 - j]).abs() < 1e-14,
                    "b={b} j={j}"
                );
            }
        }
    }

    #[test]
    fn rejects_zero_bandwidth() {
        assert!(GridAngles::new(0).is_err());
        assert!(So3Grid::zeros(0).is_err());
    }

    #[test]
    fn grid_indexing_layout() {
        let mut g = So3Grid::zeros(2).unwrap();
        let n = g.edge();
        assert_eq!(n, 4);
        g.set(1, 2, 3, Complex64::new(7.0, -1.0));
        assert_eq!(g.get(1, 2, 3), Complex64::new(7.0, -1.0));
        // slice(2) holds row i=1, col k=3 at offset 1*n + 3.
        assert_eq!(g.slice(2)[n + 3], Complex64::new(7.0, -1.0));
        assert_eq!(g.len(), 64);
    }

    #[test]
    fn from_vec_validates_length() {
        assert!(So3Grid::from_vec(2, vec![Complex64::zero(); 63]).is_err());
        assert!(So3Grid::from_vec(2, vec![Complex64::zero(); 64]).is_ok());
    }

    #[test]
    fn max_abs_error_reports_peak() {
        let mut a = So3Grid::zeros(2).unwrap();
        let b = So3Grid::zeros(2).unwrap();
        a.set(0, 0, 0, Complex64::new(0.5, 0.0));
        a.set(1, 1, 1, Complex64::new(0.0, -2.0));
        assert!((a.max_abs_error(&b) - 2.0).abs() < 1e-15);
    }
}

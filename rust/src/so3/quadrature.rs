//! Quadrature weights of the SO(3) sampling theorem (paper Eq. 6):
//!
//! `w_B(j) = (2π sin β_j / B²) · Σ_{i=0}^{B-1} sin((2i+1) β_j) / (2i+1)`.
//!
//! These make the β-sum in the FSOFT an exact quadrature for the Wigner-d
//! products of bandlimited functions:
//! `Σ_j w_B(j) d(l,·)d(l',·) = 2π/(B(2l+1)) δ_{ll'}` for l, l' < B.
//!
//! Cost is O(B²) — negligible next to the transform (the paper notes the
//! same) — but the j-loop is embarrassingly parallel and the parallel
//! executor runs it as a prologue region anyway.

use crate::error::Result;
use crate::so3::sampling::GridAngles;

/// Compute all 2B weights sequentially.
pub fn weights(b: usize) -> Result<Vec<f64>> {
    let angles = GridAngles::new(b)?;
    Ok((0..2 * b).map(|j| weight_at(b, angles.betas[j])).collect())
}

/// A single weight w_B(j) for node angle β_j.
pub fn weight_at(b: usize, beta_j: f64) -> f64 {
    let mut acc = 0.0;
    // Descending order sums the smallest terms first (they decay like 1/i),
    // which keeps the floating-point error of the partial Fourier series of
    // |sin| at the 1-ulp level.
    for i in (0..b).rev() {
        let n = (2 * i + 1) as f64;
        acc += (n * beta_j).sin() / n;
    }
    2.0 * std::f64::consts::PI * beta_j.sin() / (b * b) as f64 * acc
}

/// Diagnostic: Σ_j w_B(j) must equal 2π/B · ∫₀^π sin β dβ / 2 · 2 = 2π/B.
/// (Used by tests and the CLI `info` command.)
pub fn weight_sum_expected(b: usize) -> f64 {
    2.0 * std::f64::consts::PI / b as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::wigner::{self, WignerRowBuf};

    #[test]
    fn weights_are_positive_and_symmetric() {
        for b in [1usize, 2, 7, 16, 32] {
            let w = weights(b).unwrap();
            assert_eq!(w.len(), 2 * b);
            for (j, &wj) in w.iter().enumerate() {
                assert!(wj > 0.0, "b={b} j={j}: {wj}");
                // β-reflection symmetry of the node set ⇒ w[j] = w[2B-1-j].
                assert!((wj - w[2 * b - 1 - j]).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn weight_sum_matches_closed_form() {
        for b in [1usize, 4, 8, 32, 64] {
            let total: f64 = weights(b).unwrap().iter().sum();
            let want = weight_sum_expected(b);
            assert!(
                (total - want).abs() < 1e-12 * want,
                "b={b}: {total} vs {want}"
            );
        }
    }

    #[test]
    fn quadrature_is_exact_for_legendre_products() {
        // Σ_j w(j) d(l,0,0;β_j) d(l',0,0;β_j) = 2π/(B(2l+1)) δ_{ll'}
        // — d(l,0,0) are the Legendre polynomials, the simplest Wigner-d.
        let b = 8;
        let w = weights(b).unwrap();
        let angles = GridAngles::new(b).unwrap();
        let mut rows = vec![vec![0.0; 2 * b]; b];
        let mut buf = WignerRowBuf::new(b);
        for (j, &bj) in angles.betas.iter().enumerate() {
            wigner::d_column(b, 0, 0, bj, &mut buf);
            for l in 0..b {
                rows[l][j] = buf.values[l];
            }
        }
        for l1 in 0..b {
            for l2 in 0..b {
                let dot: f64 = (0..2 * b).map(|j| w[j] * rows[l1][j] * rows[l2][j]).sum();
                let want = if l1 == l2 {
                    2.0 * std::f64::consts::PI / (b as f64 * (2 * l1 + 1) as f64)
                } else {
                    0.0
                };
                assert!(
                    (dot - want).abs() < 1e-13,
                    "l1={l1} l2={l2}: {dot} vs {want}"
                );
            }
        }
    }

    #[test]
    fn large_bandwidth_weights_stay_sane() {
        let b = 256;
        let w = weights(b).unwrap();
        let total: f64 = w.iter().sum();
        assert!((total - weight_sum_expected(b)).abs() < 1e-10);
        assert!(w.iter().all(|&x| x > 0.0 && x < 1.0));
    }
}

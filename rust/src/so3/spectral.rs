//! Spectral utilities on SO(3): power spectra, Parseval-consistent
//! norms, and degree-wise filters (heat kernel / low-pass) — the
//! post-transform toolbox a downstream user of the FSOFT needs.
//!
//! Norm conventions (our basis, see `so3::wigner`):
//! `‖f‖² = ∫ |f|² dR = Σ_{l,m,m'} 8π²/(2l+1) |f°(l,m,m')|²`, and the
//! same integral is computed exactly on the K&R grid as
//! `(π/B) Σ_{i,j,k} w_B(j) |f(α_i, β_j, γ_k)|²` (the quadrature is
//! exact for products of two bandwidth-B functions). The agreement of
//! these two expressions — Parseval through the whole pipeline — is one
//! of the library's strongest self-tests.

use crate::error::Result;
use crate::so3::coeffs::So3Coeffs;
use crate::so3::quadrature;
use crate::so3::sampling::So3Grid;

/// Per-degree power: `P(l) = 8π²/(2l+1) Σ_{m,m'} |f°(l,m,m')|²`.
pub fn power_spectrum(coeffs: &So3Coeffs) -> Vec<f64> {
    let b = coeffs.bandwidth();
    let mut p = vec![0.0; b];
    for (l, _, _, v) in coeffs.iter() {
        p[l] += 8.0 * std::f64::consts::PI.powi(2) / (2 * l + 1) as f64 * v.norm_sqr();
    }
    p
}

/// Squared L² norm from the spectrum (Parseval).
pub fn norm_sqr_spectral(coeffs: &So3Coeffs) -> f64 {
    power_spectrum(coeffs).iter().sum()
}

/// Squared L² norm from grid samples via the exact quadrature:
/// `(π/B) Σ_{i,j,k} w_B(j) |f(i,j,k)|²`.
pub fn norm_sqr_grid(grid: &So3Grid) -> Result<f64> {
    let b = grid.bandwidth();
    let n = 2 * b;
    let w = quadrature::weights(b)?;
    let mut acc = 0.0;
    for j in 0..n {
        let mut slice_sum = 0.0;
        for v in grid.slice(j) {
            slice_sum += v.norm_sqr();
        }
        acc += w[j] * slice_sum;
    }
    Ok(acc * std::f64::consts::PI / b as f64)
}

/// Apply a degree-dependent multiplier `h(l)` in place (the general
/// spectral filter: smoothing, sharpening, band selection).
pub fn apply_degree_filter(coeffs: &mut So3Coeffs, h: impl Fn(usize) -> f64) {
    let b = coeffs.bandwidth();
    for l in 0..b {
        let li = l as i64;
        let g = h(l);
        for m in -li..=li {
            for mp in -li..=li {
                let v = coeffs.at(l, m, mp);
                *coeffs.at_mut(l, m, mp) = v.scale(g);
            }
        }
    }
}

/// Heat-kernel (Gaussian) smoothing: `f°(l) ← e^{-l(l+1)t} f°(l)` —
/// the solution of the diffusion equation on SO(3) at time t.
pub fn heat_kernel_smooth(coeffs: &mut So3Coeffs, t: f64) {
    apply_degree_filter(coeffs, |l| (-((l * (l + 1)) as f64) * t).exp());
}

/// Hard low-pass: zero all degrees `l ≥ cutoff`.
pub fn low_pass(coeffs: &mut So3Coeffs, cutoff: usize) {
    apply_degree_filter(coeffs, |l| if l < cutoff { 1.0 } else { 0.0 });
}

/// Effective bandwidth: smallest `c` such that degrees ≥ c carry less
/// than `epsilon` of the total energy.
pub fn effective_bandwidth(coeffs: &So3Coeffs, epsilon: f64) -> usize {
    let p = power_spectrum(coeffs);
    let total: f64 = p.iter().sum();
    if total == 0.0 {
        return 0;
    }
    let mut tail = 0.0;
    for l in (0..p.len()).rev() {
        tail += p[l];
        if tail > epsilon * total {
            return l + 1;
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Prop;
    use crate::transform::So3Plan;

    /// Parseval through the whole pipeline: spectral norm == grid norm.
    #[test]
    fn parseval_identity() {
        for b in [2usize, 4, 8, 16] {
            let coeffs = So3Coeffs::random(b, b as u64 + 1);
            let fft = So3Plan::new(b).unwrap();
            let grid = fft.inverse(&coeffs).unwrap();
            let ns = norm_sqr_spectral(&coeffs);
            let ng = norm_sqr_grid(&grid).unwrap();
            assert!(
                (ns - ng).abs() < 1e-10 * ns,
                "b={b}: spectral {ns} vs grid {ng}"
            );
        }
    }

    #[test]
    fn power_spectrum_isolates_degrees() {
        let b = 6;
        let mut coeffs = So3Coeffs::zeros(b);
        coeffs
            .set(3, 1, -2, crate::Complex64::new(2.0, 0.0))
            .unwrap();
        let p = power_spectrum(&coeffs);
        for (l, &pl) in p.iter().enumerate() {
            if l == 3 {
                let want = 8.0 * std::f64::consts::PI.powi(2) / 7.0 * 4.0;
                assert!((pl - want).abs() < 1e-12);
            } else {
                assert_eq!(pl, 0.0);
            }
        }
    }

    #[test]
    fn heat_kernel_contracts_and_preserves_l0() {
        let b = 8;
        let mut coeffs = So3Coeffs::random(b, 3);
        let before = power_spectrum(&coeffs);
        heat_kernel_smooth(&mut coeffs, 0.1);
        let after = power_spectrum(&coeffs);
        assert!((after[0] - before[0]).abs() < 1e-14, "l=0 is invariant");
        for l in 1..b {
            assert!(after[l] < before[l], "degree {l} must shrink");
        }
        // Decay follows e^{-2 l(l+1) t} in power.
        let ratio = after[2] / before[2];
        let want = (-2.0 * 6.0 * 0.1f64).exp();
        assert!((ratio - want).abs() < 1e-12);
    }

    #[test]
    fn low_pass_annihilates_tail() {
        let b = 8;
        let mut coeffs = So3Coeffs::random(b, 4);
        low_pass(&mut coeffs, 3);
        let p = power_spectrum(&coeffs);
        assert!(p[..3].iter().all(|&x| x > 0.0));
        assert!(p[3..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn effective_bandwidth_detects_cutoff() {
        Prop::new("effective bandwidth").cases(30).run(|g| {
            let b = g.usize_in(3, 12);
            let cut = g.usize_in(1, b);
            let mut coeffs = So3Coeffs::random(b, g.u64());
            low_pass(&mut coeffs, cut);
            let eff = effective_bandwidth(&coeffs, 1e-12);
            Prop::assert_true(
                eff <= cut,
                &format!("eff {eff} must be <= planted cutoff {cut}"),
            )
        });
    }

    #[test]
    fn filtering_commutes_with_transform() {
        // iFSOFT(h·f°) == filtered synthesis: apply filter pre-synthesis
        // vs analyze → filter → synthesize must agree.
        let b = 6;
        let fft = So3Plan::builder(b).allow_any_bandwidth().build().unwrap();
        let coeffs = So3Coeffs::random(b, 5);
        let mut pre = coeffs.clone();
        heat_kernel_smooth(&mut pre, 0.05);
        let grid_pre = fft.inverse(&pre).unwrap();

        let grid = fft.inverse(&coeffs).unwrap();
        let mut post = fft.forward(&grid).unwrap();
        heat_kernel_smooth(&mut post, 0.05);
        let grid_post = fft.inverse(&post).unwrap();

        assert!(grid_pre.max_abs_error(&grid_post) < 1e-11);
    }
}

//! Wigner-d functions `d(l, m, m'; β)` — the β-dependent core of the
//! SO(3) basis functions (paper Section 2.2).
//!
//! Implementation notes:
//!
//! * **Seeds** (paper's initial cases) are evaluated in the log domain,
//!   `exp(½(ln(2m)! − ln(m+m')! − ln(m−m')!) + (m+m')ln cos(β/2) +
//!   (m−m')ln sin(β/2))`, so they neither overflow (factorial ratios reach
//!   ~10^300 at B = 512) nor lose accuracy.
//! * **Recurrence** is the paper's three-term relation (Eq. 2), run upward
//!   in l (the numerically stable direction). At l = l₀ the coefficient of
//!   the d(l−1) term vanishes, so the recurrence self-starts from
//!   (0, seed).
//! * **Order reduction**: arbitrary (m, m') is reduced to m ≥ |m'| ≥ 0 via
//!   the symmetries `d(l,m,m') = d(l,−m',−m)` and
//!   `d(l,m,m') = (−1)^{m−m'} d(l,−m,−m')`, which introduce at most a
//!   single l-independent sign.
//! * **Convention** (verified by tests): the paper's seed+recurrence equals
//!   the Edmonds/Wikipedia explicit sum with the two orders swapped,
//!   `d_paper(l, m, m') = d_edmonds(l, m', m)`; all seven symmetries of
//!   paper Eq. 3 hold exactly.
//!
//! The row stepper is generic over the scalar so the same code runs in f64
//! and in double-double ([`crate::xprec::Dd`]) for the extended-precision
//! path the paper uses at bandwidth 512.

use crate::util::{ln_factorial, parity_sign};
use crate::xprec::Dd;

/// Scalar abstraction so the recurrence can run in f64 or double-double.
pub trait WScalar: Copy {
    /// Widen from `f64`.
    fn from_f64(x: f64) -> Self;
    /// Round back to `f64`.
    fn to_f64(self) -> f64;
    /// Sum.
    fn add(self, o: Self) -> Self;
    /// Difference.
    fn sub(self, o: Self) -> Self;
    /// Product.
    fn mul(self, o: Self) -> Self;
    /// Product with an `f64` scale.
    fn mul_f64(self, s: f64) -> Self;
}

impl WScalar for f64 {
    #[inline]
    fn from_f64(x: f64) -> Self {
        x
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn add(self, o: Self) -> Self {
        self + o
    }
    #[inline]
    fn sub(self, o: Self) -> Self {
        self - o
    }
    #[inline]
    fn mul(self, o: Self) -> Self {
        self * o
    }
    #[inline]
    fn mul_f64(self, s: f64) -> Self {
        self * s
    }
}

impl WScalar for Dd {
    #[inline]
    fn from_f64(x: f64) -> Self {
        Dd::from_f64(x)
    }
    #[inline]
    fn to_f64(self) -> f64 {
        Dd::to_f64(self)
    }
    #[inline]
    fn add(self, o: Self) -> Self {
        self + o
    }
    #[inline]
    fn sub(self, o: Self) -> Self {
        self - o
    }
    #[inline]
    fn mul(self, o: Self) -> Self {
        self * o
    }
    #[inline]
    fn mul_f64(self, s: f64) -> Self {
        Dd::mul_f64(self, s)
    }
}

/// Reduced order pair: m ≥ |m'| ≥ 0 plus the sign of the reduction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReducedOrders {
    /// Reduced order μ.
    pub m: i64,
    /// Reduced order μ'.
    pub mp: i64,
    /// +1 or −1; `d(l, m_orig, mp_orig) = sign · d(l, m, mp)` for all l.
    pub sign: f64,
}

/// Reduce (m, m') to the canonical domain m ≥ |m'| ≥ 0.
pub fn reduce_orders(mut m: i64, mut mp: i64) -> ReducedOrders {
    let mut sign = 1.0;
    if mp.abs() > m.abs() {
        // d(l, m, m') = d(l, -m', -m) — paper Eq. 3 line 7, no sign.
        let (nm, nmp) = (-mp, -m);
        m = nm;
        mp = nmp;
    }
    if m < 0 {
        // d(l, m, m') = (-1)^{m-m'} d(l, -m, -m') — Eq. 3 line 1.
        sign = parity_sign(m - mp);
        m = -m;
        mp = -mp;
    }
    debug_assert!(m >= mp.abs());
    ReducedOrders { m, mp, sign }
}

/// Lowest degree carrying the order pair: l₀ = max(|m|, |m'|).
#[inline]
pub fn l_min(m: i64, mp: i64) -> usize {
    m.abs().max(mp.abs()) as usize
}

/// Log-domain seed `d(m, m, m'; β)` for the reduced domain m ≥ |m'|.
/// β must lie strictly inside (0, π) — true for every grid node.
/// Public for the Clenshaw dataflow, which seeds per β-node.
pub fn d_seed(m: i64, mp: i64, beta: f64) -> f64 {
    debug_assert!(m >= mp.abs());
    if m == 0 {
        return 1.0;
    }
    let half = 0.5 * beta;
    let (s, c) = half.sin_cos();
    debug_assert!(s > 0.0 && c > 0.0, "β must be in (0, π)");
    let ln_mag = 0.5
        * (ln_factorial((2 * m) as u64)
            - ln_factorial((m + mp) as u64)
            - ln_factorial((m - mp) as u64))
        + (m + mp) as f64 * c.ln()
        + (m - mp) as f64 * s.ln();
    ln_mag.exp()
}

/// Recurrence coefficients for the step l → l+1 at fixed (m, m'):
/// `d_{l+1} = (a1·cosβ + a2)·d_l − a3·d_{l−1}`.
#[derive(Debug, Clone, Copy)]
pub struct StepCoeffs {
    /// Coefficient of `x · d_{l-1}` in the three-term recurrence.
    pub a1: f64,
    /// Coefficient of `d_{l-1}` in the three-term recurrence.
    pub a2: f64,
    /// Coefficient of `d_{l-2}` in the three-term recurrence.
    pub a3: f64,
}

/// Coefficients of paper Eq. 2 (valid for l ≥ 1; l = 0 only occurs for
/// m = m' = 0 where the step is simply d₁ = cosβ).
pub fn step_coeffs(l: usize, m: i64, mp: i64) -> StepCoeffs {
    debug_assert!(l >= 1);
    let lf = l as f64;
    let l1 = lf + 1.0;
    let m2 = (m * m) as f64;
    let mp2 = (mp * mp) as f64;
    let norm = ((l1 * l1 - m2) * (l1 * l1 - mp2)).sqrt();
    let a1 = (2.0 * lf + 1.0) * l1 / norm;
    let a2 = -(2.0 * lf + 1.0) * (m * mp) as f64 / (lf * norm);
    let a3 = l1 / lf * ((lf * lf - m2) * (lf * lf - mp2)).sqrt() / norm;
    StepCoeffs { a1, a2, a3 }
}

/// Streaming generator of Wigner-d **rows over the β grid**: successive
/// calls produce `d(l, m, m'; β_j)` for l = l₀, l₀+1, … and all j at once.
/// This is the l-outer order the DWT wants, and it never materializes the
/// full (B−l₀)×2B table.
pub struct WignerRowStepper<R: WScalar = f64> {
    m: i64,
    mp: i64,
    sign: f64,
    l0: usize,
    /// Degree of the row `cur` currently holds (the next row returned).
    l: usize,
    cos_betas: Vec<f64>,
    prev: Vec<R>,
    cur: Vec<R>,
}

impl<R: WScalar> WignerRowStepper<R> {
    /// Prepare a stepper for (possibly unreduced) orders at the given
    /// β nodes.
    pub fn new(m: i64, mp: i64, betas: &[f64]) -> Self {
        let red = reduce_orders(m, mp);
        let l0 = l_min(red.m, red.mp);
        let n = betas.len();
        let mut cur = Vec::with_capacity(n);
        for &b in betas {
            cur.push(R::from_f64(red.sign * d_seed(red.m, red.mp, b)));
        }
        Self {
            m: red.m,
            mp: red.mp,
            sign: red.sign,
            l0,
            l: l0,
            cos_betas: betas.iter().map(|&b| b.cos()).collect(),
            prev: vec![R::from_f64(0.0); n],
            cur,
        }
    }

    /// Lowest degree l₀ of this order pair.
    #[inline]
    pub fn l_min(&self) -> usize {
        self.l0
    }

    /// Degree of the row the next `row()` call returns.
    #[inline]
    pub fn current_l(&self) -> usize {
        self.l
    }

    /// Borrow the current row (degree `current_l()`), values over j.
    #[inline]
    pub fn row(&self) -> &[R] {
        &self.cur
    }

    /// Advance to the next degree.
    pub fn advance(&mut self) {
        let l = self.l;
        if l == 0 {
            // Only reachable for m = m' = 0: d₁(β) = cosβ · d₀(β).
            for (j, p) in self.prev.iter_mut().enumerate() {
                let c = self.cur[j];
                *p = c;
                self.cur[j] = c.mul_f64(self.cos_betas[j]);
            }
        } else {
            let StepCoeffs { a1, a2, a3 } = step_coeffs(l, self.m, self.mp);
            for j in 0..self.cur.len() {
                let c = self.cur[j];
                let p = self.prev[j];
                let factor = a1 * self.cos_betas[j] + a2;
                let next = c.mul_f64(factor).sub(p.mul_f64(a3));
                self.prev[j] = c;
                self.cur[j] = next;
            }
        }
        self.l += 1;
    }

    /// Reduction sign actually applied to the seed (diagnostics).
    #[inline]
    pub fn reduction_sign(&self) -> f64 {
        self.sign
    }
}

/// Scratch buffer for [`d_column`]: values indexed by l (0..B); entries
/// below l₀ are zero.
#[derive(Debug, Clone)]
pub struct WignerRowBuf {
    /// Row values, one per β sample.
    pub values: Vec<f64>,
}

impl WignerRowBuf {
    /// Row buffer for bandwidth `b` (2B samples).
    pub fn new(b: usize) -> Self {
        Self {
            values: vec![0.0; b],
        }
    }
}

/// Fill `buf.values[l] = d(l, m, m'; β)` for l = l₀..B−1 (zeros below l₀).
/// Column-wise access — used by oracles, apps, and tests; the transform
/// hot path uses [`WignerRowStepper`] instead.
pub fn d_column(b: usize, m: i64, mp: i64, beta: f64, buf: &mut WignerRowBuf) {
    assert!(buf.values.len() >= b);
    for v in buf.values[..b].iter_mut() {
        *v = 0.0;
    }
    let mut stepper: WignerRowStepper<f64> = WignerRowStepper::new(m, mp, &[beta]);
    let l0 = stepper.l_min();
    for l in l0..b {
        buf.values[l] = stepper.row()[0];
        if l + 1 < b {
            stepper.advance();
        }
    }
}

/// Single value d(l, m, m'; β) via the recurrence.
pub fn d_single(l: usize, m: i64, mp: i64, beta: f64) -> f64 {
    let l0 = l_min(m, mp);
    if l < l0 {
        return 0.0;
    }
    let mut stepper: WignerRowStepper<f64> = WignerRowStepper::new(m, mp, &[beta]);
    for _ in l0..l {
        stepper.advance();
    }
    stepper.row()[0]
}

/// Explicit-sum oracle in the paper's convention:
/// `d_paper(l, m, m') = d_edmonds(l, m', m)` (see module docs).
/// O(l) terms; used only in tests and small-scale reference paths.
pub fn d_explicit(l: i64, m: i64, mp: i64, beta: f64) -> f64 {
    // Evaluate the Edmonds sum with orders swapped: a = m', b = m.
    let (a, b) = (mp, m);
    if m.abs() > l || mp.abs() > l {
        return 0.0;
    }
    let half = 0.5 * beta;
    let (s, c) = half.sin_cos();
    let k_lo = 0.max(b - a);
    let k_hi = (l + b).min(l - a);
    let mut total = 0.0;
    let pref = 0.5
        * (ln_factorial((l + a) as u64)
            + ln_factorial((l - a) as u64)
            + ln_factorial((l + b) as u64)
            + ln_factorial((l - b) as u64));
    for k in k_lo..=k_hi {
        let den = ln_factorial((l + b - k) as u64)
            + ln_factorial(k as u64)
            + ln_factorial((a - b + k) as u64)
            + ln_factorial((l - a - k) as u64);
        let cpow = 2 * l + b - a - 2 * k;
        let spow = a - b + 2 * k;
        // Angles are interior, so ln c / ln s are finite; still guard the
        // zero-exponent cases to avoid 0·(-inf).
        let ln_cs = if cpow == 0 { 0.0 } else { cpow as f64 * c.ln() }
            + if spow == 0 { 0.0 } else { spow as f64 * s.ln() };
        total += parity_sign(a - b + k) * (pref - den + ln_cs).exp();
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::sampling::GridAngles;
    use crate::testkit::Prop;

    const PI: f64 = std::f64::consts::PI;

    #[test]
    fn seed_matches_paper_formula_small_cases() {
        // d(1, 1, 0; β) = √2 cos(β/2) sin(β/2) = sinβ/√2.
        for &beta in &[0.3, 1.1, 2.7] {
            let got = d_single(1, 1, 0, beta);
            let want = beta.sin() / 2.0_f64.sqrt();
            assert!((got - want).abs() < 1e-14, "{got} vs {want}");
        }
        // d(1, 1, 1; β) = cos²(β/2) = (1+cosβ)/2.
        for &beta in &[0.3, 1.1, 2.7] {
            let got = d_single(1, 1, 1, beta);
            let want = (1.0 + beta.cos()) / 2.0;
            assert!((got - want).abs() < 1e-14);
        }
        // d(1, 1, -1; β) = sin²(β/2) = (1-cosβ)/2.
        for &beta in &[0.3, 1.1, 2.7] {
            let got = d_single(1, 1, -1, beta);
            let want = (1.0 - beta.cos()) / 2.0;
            assert!((got - want).abs() < 1e-14);
        }
    }

    #[test]
    fn legendre_special_case() {
        // d(l, 0, 0; β) = P_l(cosβ).
        for &beta in &[0.4f64, 1.3, 2.2] {
            let x = beta.cos();
            assert!((d_single(0, 0, 0, beta) - 1.0).abs() < 1e-15);
            assert!((d_single(1, 0, 0, beta) - x).abs() < 1e-15);
            assert!((d_single(2, 0, 0, beta) - (1.5 * x * x - 0.5)).abs() < 1e-14);
            assert!(
                (d_single(3, 0, 0, beta) - (2.5 * x * x * x - 1.5 * x)).abs() < 1e-14
            );
        }
    }

    #[test]
    fn recurrence_matches_explicit_oracle() {
        Prop::new("wigner recurrence vs explicit sum")
            .cases(300)
            .run(|g| {
                let l = g.i64_in(0, 24);
                let m = if l == 0 { 0 } else { g.i64_in(-l, l) };
                let mp = if l == 0 { 0 } else { g.i64_in(-l, l) };
                let beta = g.f64_in(0.02, PI - 0.02);
                let fast = d_single(l as usize, m, mp, beta);
                let slow = d_explicit(l, m, mp, beta);
                // The explicit sum cancels heavily (alternating huge
                // terms), so its own accuracy bounds the tolerance here;
                // the machine-precision check is quadrature orthogonality.
                Prop::assert_close(fast, slow, 1e-7, "d recur vs explicit")
            });
    }

    #[test]
    fn all_seven_symmetries_hold() {
        Prop::new("paper Eq. 3 symmetries").cases(300).run(|g| {
            let l = g.i64_in(1, 20);
            let m = g.i64_in(-l, l);
            let mp = g.i64_in(-l, l);
            let beta = g.f64_in(0.02, PI - 0.02);
            let d = d_single(l as usize, m, mp, beta);
            let cases: [(f64, f64, &str); 7] = [
                (parity_sign(m - mp), d_single(l as usize, -m, -mp, beta), "line1"),
                (parity_sign(m - mp), d_single(l as usize, mp, m, beta), "line2"),
                (parity_sign(l - mp), d_single(l as usize, -m, mp, PI - beta), "line3"),
                (parity_sign(l + m), d_single(l as usize, m, -mp, PI - beta), "line4"),
                (parity_sign(l - mp), d_single(l as usize, -mp, m, PI - beta), "line5"),
                (parity_sign(l + m), d_single(l as usize, mp, -m, PI - beta), "line6"),
                (1.0, d_single(l as usize, -mp, -m, beta), "line7"),
            ];
            for (sign, val, name) in cases {
                Prop::assert_close(sign * val, d, 1e-10, name)?;
            }
            Ok(())
        });
    }

    #[test]
    fn stepper_rows_match_columns() {
        let b = 12;
        let angles = GridAngles::new(b).unwrap();
        for &(m, mp) in &[(0i64, 0i64), (3, 1), (-5, 2), (2, -7), (11, 11), (11, -11)] {
            let mut stepper: WignerRowStepper<f64> =
                WignerRowStepper::new(m, mp, &angles.betas);
            let l0 = stepper.l_min();
            let mut buf = WignerRowBuf::new(b);
            for l in l0..b {
                let row = stepper.row().to_vec();
                for (j, &bj) in angles.betas.iter().enumerate() {
                    d_column(b, m, mp, bj, &mut buf);
                    assert!(
                        (row[j] - buf.values[l]).abs() < 1e-12,
                        "m={m} mp={mp} l={l} j={j}"
                    );
                }
                if l + 1 < b {
                    stepper.advance();
                }
            }
        }
    }

    #[test]
    fn values_bounded_by_one() {
        // |d(l,m,m')| ≤ 1 always; check deep degrees for stability.
        let betas: Vec<f64> = (0..32)
            .map(|j| (2 * j + 1) as f64 * PI / 128.0)
            .collect();
        for &(m, mp) in &[(0i64, 0i64), (10, 5), (60, -30), (100, 100)] {
            let mut st: WignerRowStepper<f64> = WignerRowStepper::new(m, mp, &betas);
            for _ in st.l_min()..512 {
                for &v in st.row() {
                    assert!(v.abs() <= 1.0 + 1e-9, "m={m} mp={mp}: {v}");
                    assert!(v.is_finite());
                }
                st.advance();
            }
        }
    }

    #[test]
    fn dd_stepper_agrees_with_f64() {
        let betas: Vec<f64> = (0..16).map(|j| (2 * j + 1) as f64 * PI / 64.0).collect();
        let mut f: WignerRowStepper<f64> = WignerRowStepper::new(4, -2, &betas);
        let mut x: WignerRowStepper<Dd> = WignerRowStepper::new(4, -2, &betas);
        for _ in 0..40 {
            for (a, b) in f.row().iter().zip(x.row().iter()) {
                assert!((a - b.to_f64()).abs() < 1e-12);
            }
            f.advance();
            x.advance();
        }
    }

    #[test]
    fn reduce_orders_covers_all_quadrants() {
        Prop::new("order reduction").cases(200).run(|g| {
            let m = g.i64_in(-30, 30);
            let mp = g.i64_in(-30, 30);
            let r = reduce_orders(m, mp);
            Prop::assert_true(r.m >= r.mp.abs(), "canonical domain")?;
            Prop::assert_true(r.sign == 1.0 || r.sign == -1.0, "sign is ±1")?;
            // The reduction must preserve the function value.
            let beta = g.f64_in(0.1, PI - 0.1);
            let l = (r.m.abs().max(30)) as usize;
            let direct = d_explicit(l as i64, m, mp, beta);
            let reduced = r.sign * d_explicit(l as i64, r.m, r.mp, beta);
            // Tolerance bounded by the explicit sum's cancellation error.
            Prop::assert_close(direct, reduced, 1e-6, "reduction preserves d")
        });
    }

    #[test]
    fn seed_underflow_is_graceful() {
        // Extreme order at a near-axial angle: the true value underflows;
        // we must return 0.0, not NaN/inf.
        let betas = [1e-3];
        let st: WignerRowStepper<f64> = WignerRowStepper::new(500, 0, &betas);
        let v = st.row()[0];
        assert!(v == 0.0 || v.is_finite());
    }
}

//! Rotation matrices and the z-y-z Euler-angle parameterization.
//!
//! `R(α, β, γ) = R_z(γ) · R_y(β) · R_z(α)` — paper Section 2.1.

use std::ops::Mul;

/// A 3×3 rotation matrix (row-major).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rotation {
    /// Row-major 3×3 rotation matrix.
    pub m: [[f64; 3]; 3],
}

/// z-y-z Euler angles: α, γ ∈ [0, 2π), β ∈ [0, π].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EulerZyz {
    /// First z-rotation angle α ∈ [0, 2π).
    pub alpha: f64,
    /// y-rotation angle β ∈ [0, π].
    pub beta: f64,
    /// Second z-rotation angle γ ∈ [0, 2π).
    pub gamma: f64,
}

impl Rotation {
    /// The identity rotation.
    pub const IDENTITY: Rotation = Rotation {
        m: [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]],
    };

    /// Elementary rotation about the x axis.
    pub fn about_x(a: f64) -> Rotation {
        let (s, c) = a.sin_cos();
        Rotation {
            m: [[1.0, 0.0, 0.0], [0.0, c, -s], [0.0, s, c]],
        }
    }

    /// Elementary rotation about the y axis.
    pub fn about_y(a: f64) -> Rotation {
        let (s, c) = a.sin_cos();
        Rotation {
            m: [[c, 0.0, s], [0.0, 1.0, 0.0], [-s, 0.0, c]],
        }
    }

    /// Elementary rotation about the z axis.
    pub fn about_z(a: f64) -> Rotation {
        let (s, c) = a.sin_cos();
        Rotation {
            m: [[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]],
        }
    }

    /// Compose from z-y-z Euler angles: `R_z(γ) R_y(β) R_z(α)`.
    pub fn from_euler(e: EulerZyz) -> Rotation {
        Rotation::about_z(e.gamma) * Rotation::about_y(e.beta) * Rotation::about_z(e.alpha)
    }

    /// Transpose (= inverse for rotations).
    pub fn transpose(&self) -> Rotation {
        let mut t = [[0.0; 3]; 3];
        for (r, row) in self.m.iter().enumerate() {
            for (c, &v) in row.iter().enumerate() {
                t[c][r] = v;
            }
        }
        Rotation { m: t }
    }

    /// Inverse rotation.
    #[inline]
    pub fn inverse(&self) -> Rotation {
        self.transpose()
    }

    /// Apply to a vector.
    pub fn apply(&self, v: [f64; 3]) -> [f64; 3] {
        let mut out = [0.0; 3];
        for (r, row) in self.m.iter().enumerate() {
            out[r] = row[0] * v[0] + row[1] * v[1] + row[2] * v[2];
        }
        out
    }

    /// Determinant (≈ 1 for proper rotations).
    pub fn det(&self) -> f64 {
        let m = &self.m;
        m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
            - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
            + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
    }

    /// Frobenius distance to another rotation.
    pub fn frobenius_distance(&self, other: &Rotation) -> f64 {
        let mut acc = 0.0;
        for r in 0..3 {
            for c in 0..3 {
                let d = self.m[r][c] - other.m[r][c];
                acc += d * d;
            }
        }
        acc.sqrt()
    }

    /// Geodesic (angular) distance in radians: arccos((tr(R₁ᵀR₂) − 1)/2).
    pub fn angular_distance(&self, other: &Rotation) -> f64 {
        let rel = self.transpose() * *other;
        let tr = rel.m[0][0] + rel.m[1][1] + rel.m[2][2];
        ((tr - 1.0) / 2.0).clamp(-1.0, 1.0).acos()
    }

    /// Recover z-y-z Euler angles. For β ≈ 0 or π (gimbal lock) the split
    /// between α and γ is not unique; we set γ = 0 there.
    pub fn to_euler(&self) -> EulerZyz {
        let m = &self.m;
        // R = Rz(γ)Ry(β)Rz(α) ⇒ m[2][2] = cos β,
        // m[0][2] = sin β cos γ, m[1][2] = sin β sin γ,
        // m[2][0] = -sin β cos α, m[2][1] = sin β sin α.
        let beta = m[2][2].clamp(-1.0, 1.0).acos();
        let tau = std::f64::consts::TAU;
        if beta.sin().abs() < 1e-12 {
            // Gimbal lock: only α ± γ is defined.
            let angle = m[1][0].atan2(m[0][0]);
            if m[2][2] > 0.0 {
                // β = 0: R = Rz(α + γ).
                EulerZyz {
                    alpha: angle.rem_euclid(tau),
                    beta: 0.0,
                    gamma: 0.0,
                }
            } else {
                // β = π: R = Rz(γ - α) · diag-ish flip.
                EulerZyz {
                    alpha: (-angle).rem_euclid(tau),
                    beta: std::f64::consts::PI,
                    gamma: 0.0,
                }
            }
        } else {
            let gamma = m[1][2].atan2(m[0][2]);
            let alpha = m[2][1].atan2(-m[2][0]);
            EulerZyz {
                alpha: alpha.rem_euclid(tau),
                beta,
                gamma: gamma.rem_euclid(tau),
            }
        }
    }
}

impl Mul for Rotation {
    type Output = Rotation;
    fn mul(self, o: Rotation) -> Rotation {
        let mut out = [[0.0; 3]; 3];
        for (r, orow) in out.iter_mut().enumerate() {
            for (c, cell) in orow.iter_mut().enumerate() {
                *cell = (0..3).map(|k| self.m[r][k] * o.m[k][c]).sum();
            }
        }
        Rotation { m: out }
    }
}

impl EulerZyz {
    /// Euler angles in zyz convention.
    pub fn new(alpha: f64, beta: f64, gamma: f64) -> Self {
        Self { alpha, beta, gamma }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{Gen, Prop};

    fn random_euler(g: &mut Gen) -> EulerZyz {
        EulerZyz::new(
            g.f64_in(0.0, std::f64::consts::TAU),
            g.f64_in(0.05, std::f64::consts::PI - 0.05),
            g.f64_in(0.0, std::f64::consts::TAU),
        )
    }

    #[test]
    fn elementary_rotations_are_orthogonal() {
        for r in [
            Rotation::about_x(0.7),
            Rotation::about_y(-1.2),
            Rotation::about_z(2.9),
        ] {
            let should_be_id = r * r.transpose();
            assert!(should_be_id.frobenius_distance(&Rotation::IDENTITY) < 1e-14);
            assert!((r.det() - 1.0).abs() < 1e-14);
        }
    }

    #[test]
    fn euler_roundtrip_property() {
        Prop::new("euler zyz roundtrip").cases(200).run(|g| {
            let e = random_euler(g);
            let r = Rotation::from_euler(e);
            let e2 = r.to_euler();
            let r2 = Rotation::from_euler(e2);
            Prop::assert_close(r.frobenius_distance(&r2), 0.0, 1e-10, "R(e) vs R(to_euler)")
        });
    }

    #[test]
    fn composition_is_associative() {
        Prop::new("rotation associativity").cases(100).run(|g| {
            let a = Rotation::from_euler(random_euler(g));
            let b = Rotation::from_euler(random_euler(g));
            let c = Rotation::from_euler(random_euler(g));
            let lhs = (a * b) * c;
            let rhs = a * (b * c);
            Prop::assert_close(lhs.frobenius_distance(&rhs), 0.0, 1e-12, "(ab)c vs a(bc)")
        });
    }

    #[test]
    fn inverse_undoes_rotation() {
        Prop::new("inverse").cases(100).run(|g| {
            let r = Rotation::from_euler(random_euler(g));
            let v = [g.signed_unit(), g.signed_unit(), g.signed_unit()];
            let w = r.inverse().apply(r.apply(v));
            Prop::assert_close(
                (0..3).map(|i| (v[i] - w[i]).powi(2)).sum::<f64>().sqrt(),
                0.0,
                1e-12,
                "R⁻¹Rv vs v",
            )
        });
    }

    #[test]
    fn gimbal_lock_recovery() {
        // β = 0: rotation reduces to Rz(α + γ).
        let e = EulerZyz::new(0.4, 0.0, 1.1);
        let r = Rotation::from_euler(e);
        let back = r.to_euler();
        assert!((back.beta).abs() < 1e-12);
        let r2 = Rotation::from_euler(back);
        assert!(r.frobenius_distance(&r2) < 1e-12);
    }

    #[test]
    fn angular_distance_of_known_pair() {
        let a = Rotation::IDENTITY;
        let b = Rotation::about_z(0.5);
        assert!((a.angular_distance(&b) - 0.5).abs() < 1e-12);
        assert!((a.angular_distance(&a)).abs() < 1e-7);
    }

    #[test]
    fn apply_preserves_norm() {
        Prop::new("isometry").cases(100).run(|g| {
            let r = Rotation::from_euler(random_euler(g));
            let v = [g.signed_unit(), g.signed_unit(), g.signed_unit()];
            let n1 = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
            let w = r.apply(v);
            let n2 = (w[0] * w[0] + w[1] * w[1] + w[2] * w[2]).sqrt();
            Prop::assert_close(n1, n2, 1e-12, "|Rv| vs |v|")
        });
    }
}

//! Mathematics of the rotation group SO(3).
//!
//! * [`rotation`] — rotation matrices and the z-y-z Euler parameterization.
//! * [`sampling`] — the Kostelec–Rockmore sampling grid (α_i, β_j, γ_k) and
//!   the grid-value container used by the transforms.
//! * [`quadrature`] — the quadrature weights w_B(j) of the SO(3) sampling
//!   theorem (paper Eq. 6).
//! * [`wigner`] — Wigner-d functions: log-domain seeds, the three-term
//!   recurrence (paper Eq. 2), the seven symmetries (paper Eq. 3), and an
//!   explicit-sum oracle for tests.
//! * [`coeffs`] — the SO(3) Fourier coefficient container with (l, m, m')
//!   indexing.
//!
//! Convention note (validated numerically in the test suite): the paper's
//! seed + recurrence realizes `d_paper(l, m, m') = d_edmonds(l, m', m)`,
//! where `d_edmonds` is the Wikipedia/Edmonds explicit sum. All seven
//! symmetries of paper Eq. 3 hold exactly for this convention, and the
//! quadrature orthogonality reads
//! `Σ_j w_B(j) d(l,m,m';β_j) d(l',m,m';β_j) = 2π/(B(2l+1)) δ_{ll'}`.

pub mod coeffs;
pub mod quadrature;
pub mod rotation;
pub mod sampling;
pub mod spectral;
pub mod wigner;

//! The SO(3) Fourier coefficient container.
//!
//! A bandwidth-B function has `B(4B²−1)/3` coefficients `f°(l, m, m')`
//! with l < B and |m|, |m'| ≤ l. They are stored flat, l-major, each
//! degree-l block a row-major (2l+1)×(2l+1) matrix over (m, m'):
//!
//! `index(l, m, m') = l(4l²−1)/3 + (m+l)(2l+1) + (m'+l)`.
//!
//! The degree-block offset `l(4l²−1)/3 = Σ_{j<l} (2j+1)²` is the closed
//! form the paper quotes via "Gauss' well-known formula".

use crate::error::{Error, Result};
use crate::fft::Complex64;
use crate::prng::Xoshiro256;

/// Number of coefficients for bandwidth B: B(4B²−1)/3.
#[inline]
pub fn coeff_count(b: usize) -> usize {
    b * (4 * b * b - 1) / 3
}

/// Flat offset of the degree-l block.
#[inline]
pub fn degree_offset(l: usize) -> usize {
    // l(4l²−1)/3, written to avoid the l = 0 underflow of `4l²−1`.
    l * (4 * l * l).saturating_sub(1) / 3
}

/// Flat index of (l, m, m'); caller guarantees |m|, |m'| ≤ l.
#[inline]
pub fn flat_index(l: usize, m: i64, mp: i64) -> usize {
    let li = l as i64;
    debug_assert!(m.abs() <= li && mp.abs() <= li);
    degree_offset(l) + ((m + li) * (2 * li + 1) + (mp + li)) as usize
}

/// Coefficients of a bandlimited function on SO(3).
#[derive(Debug, Clone, PartialEq)]
pub struct So3Coeffs {
    b: usize,
    data: Vec<Complex64>,
}

impl So3Coeffs {
    /// All-zero coefficients.
    pub fn zeros(b: usize) -> Self {
        assert!(b >= 1, "bandwidth must be >= 1");
        Self {
            b,
            data: vec![Complex64::zero(); coeff_count(b)],
        }
    }

    /// The paper's benchmark workload: every coefficient's real and
    /// imaginary part uniform on [-1, 1], deterministic in `seed`.
    pub fn random(b: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut c = Self::zeros(b);
        for v in c.data.iter_mut() {
            *v = Complex64::new(rng.next_signed(), rng.next_signed());
        }
        c
    }

    /// Wrap an existing flat buffer (must be `coeff_count(b)` long).
    pub fn from_vec(b: usize, data: Vec<Complex64>) -> Result<Self> {
        if data.len() != coeff_count(b) {
            return Err(Error::shape(
                coeff_count(b),
                data.len(),
                "So3Coeffs::from_vec",
            ));
        }
        Ok(Self { b, data })
    }

    /// Bandwidth B of this coefficient set.
    #[inline]
    pub fn bandwidth(&self) -> usize {
        self.b
    }

    /// Total number of stored coefficients.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the storage is empty.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Checked access.
    pub fn get(&self, l: usize, m: i64, mp: i64) -> Result<Complex64> {
        self.check(l, m, mp)?;
        Ok(self.data[flat_index(l, m, mp)])
    }

    /// Checked write.
    pub fn set(&mut self, l: usize, m: i64, mp: i64, v: Complex64) -> Result<()> {
        self.check(l, m, mp)?;
        self.data[flat_index(l, m, mp)] = v;
        Ok(())
    }

    /// Unchecked (debug-asserted) access for hot paths.
    #[inline]
    pub fn at(&self, l: usize, m: i64, mp: i64) -> Complex64 {
        self.data[flat_index(l, m, mp)]
    }

    /// Mutable coefficient `f(l, m, m')`.
    #[inline]
    pub fn at_mut(&mut self, l: usize, m: i64, mp: i64) -> &mut Complex64 {
        &mut self.data[flat_index(l, m, mp)]
    }

    fn check(&self, l: usize, m: i64, mp: i64) -> Result<()> {
        let li = l as i64;
        if l >= self.b || m.abs() > li || mp.abs() > li {
            return Err(Error::IndexOutOfRange {
                l: li,
                m,
                mp,
                b: self.b,
            });
        }
        Ok(())
    }

    /// Flat coefficient storage.
    pub fn as_slice(&self) -> &[Complex64] {
        &self.data
    }

    /// Flat mutable coefficient storage.
    pub fn as_mut_slice(&mut self) -> &mut [Complex64] {
        &mut self.data
    }

    /// The flat storage, consuming `self`.
    pub fn into_vec(self) -> Vec<Complex64> {
        self.data
    }

    /// Iterate (l, m, m', value).
    pub fn iter(&self) -> impl Iterator<Item = (usize, i64, i64, Complex64)> + '_ {
        (0..self.b).flat_map(move |l| {
            let li = l as i64;
            (-li..=li).flat_map(move |m| {
                (-li..=li).map(move |mp| (l, m, mp, self.data[flat_index(l, m, mp)]))
            })
        })
    }

    /// Max |difference| against another coefficient set.
    pub fn max_abs_error(&self, other: &So3Coeffs) -> f64 {
        assert_eq!(self.b, other.b, "bandwidth mismatch");
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (*a - *b).abs())
            .fold(0.0, f64::max)
    }

    /// Max relative error |Δ|/|ref| over coefficients of `self` (the
    /// paper's Table 1 second column; `self` is the reference f°).
    pub fn max_rel_error(&self, other: &So3Coeffs) -> f64 {
        assert_eq!(self.b, other.b, "bandwidth mismatch");
        self.data
            .iter()
            .zip(other.data.iter())
            .filter(|(a, _)| a.abs() > 0.0)
            .map(|(a, b)| (*a - *b).abs() / a.abs())
            .fold(0.0, f64::max)
    }

    /// Squared L² norm of the function (by Parseval for our basis):
    /// `‖f‖² = Σ 8π²/(2l+1) |f°(l,m,m')|²`.
    pub fn norm_sqr(&self) -> f64 {
        let mut acc = 0.0;
        for (l, _, _, v) in self.iter() {
            acc += 8.0 * std::f64::consts::PI.powi(2) / (2 * l + 1) as f64 * v.norm_sqr();
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Prop;

    #[test]
    fn count_matches_closed_form() {
        // Σ_{l<B} (2l+1)² computed directly.
        for b in 1..=20usize {
            let direct: usize = (0..b).map(|l| (2 * l + 1) * (2 * l + 1)).sum();
            assert_eq!(coeff_count(b), direct, "b={b}");
        }
        assert_eq!(coeff_count(1), 1);
        assert_eq!(coeff_count(2), 10);
        // The paper's B=512 count.
        assert_eq!(coeff_count(512), 512 * (4 * 512 * 512 - 1) / 3);
    }

    #[test]
    fn flat_index_is_bijective() {
        let b = 9;
        let mut seen = vec![false; coeff_count(b)];
        for l in 0..b {
            let li = l as i64;
            for m in -li..=li {
                for mp in -li..=li {
                    let idx = flat_index(l, m, mp);
                    assert!(!seen[idx], "duplicate index {idx} at ({l},{m},{mp})");
                    seen[idx] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "index map must be surjective");
    }

    #[test]
    fn get_set_roundtrip_and_bounds() {
        let mut c = So3Coeffs::zeros(4);
        c.set(3, -2, 1, Complex64::new(1.5, -0.5)).unwrap();
        assert_eq!(c.get(3, -2, 1).unwrap(), Complex64::new(1.5, -0.5));
        assert!(c.get(4, 0, 0).is_err(), "l out of range");
        assert!(c.get(2, 3, 0).is_err(), "m out of range");
        assert!(c.set(2, 0, -3, Complex64::zero()).is_err());
    }

    #[test]
    fn random_is_deterministic_and_in_range() {
        let a = So3Coeffs::random(6, 99);
        let b = So3Coeffs::random(6, 99);
        assert_eq!(a, b);
        let c = So3Coeffs::random(6, 100);
        assert_ne!(a, c);
        for (_, _, _, v) in a.iter() {
            assert!(v.re >= -1.0 && v.re < 1.0);
            assert!(v.im >= -1.0 && v.im < 1.0);
        }
    }

    #[test]
    fn iter_visits_every_coefficient_once() {
        let c = So3Coeffs::random(5, 1);
        assert_eq!(c.iter().count(), coeff_count(5));
        let mut seen = vec![false; coeff_count(5)];
        for (l, m, mp, _) in c.iter() {
            let idx = flat_index(l, m, mp);
            assert!(!seen[idx]);
            seen[idx] = true;
        }
    }

    #[test]
    fn error_metrics() {
        let mut a = So3Coeffs::zeros(3);
        let mut b = So3Coeffs::zeros(3);
        a.set(2, 1, -1, Complex64::new(2.0, 0.0)).unwrap();
        b.set(2, 1, -1, Complex64::new(2.5, 0.0)).unwrap();
        assert!((a.max_abs_error(&b) - 0.5).abs() < 1e-15);
        assert!((a.max_rel_error(&b) - 0.25).abs() < 1e-15);
    }

    #[test]
    fn index_property_random_probes() {
        Prop::new("coeff index in range").cases(200).run(|g| {
            let b = g.usize_in(1, 32);
            let l = g.usize_in(0, b - 1);
            let li = l as i64;
            let m = g.i64_in(-li, li);
            let mp = g.i64_in(-li, li);
            let idx = flat_index(l, m, mp);
            Prop::assert_true(idx < coeff_count(b), "index below count")?;
            Prop::assert_true(idx >= degree_offset(l), "index in degree block")?;
            Prop::assert_true(idx < degree_offset(l + 1), "index before next block")
        });
    }
}

"""Pallas DWT kernels vs the pure-jnp oracle — the core L1 correctness
signal. Hypothesis sweeps shapes and dtypes; fixed cases cover the exact
artifact shapes."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dwt_pallas, ref


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-1, 1, size=shape), dtype=dtype)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 8),
    l=st.integers(1, 48),
    j=st.integers(1, 48),
    dtype=st.sampled_from([jnp.float32, jnp.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_forward_kernel_matches_ref(m, l, j, dtype, seed):
    d = _rand((l, j), dtype, seed)
    t = _rand((m, j), dtype, seed + 1)
    got = dwt_pallas.dwt_contract_forward(d, t)
    want = ref.dwt_contract_forward_ref(d, t)
    tol = 1e-12 if dtype == jnp.float64 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol * j)
    assert got.dtype == dtype


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 8),
    l=st.integers(1, 48),
    j=st.integers(1, 48),
    dtype=st.sampled_from([jnp.float32, jnp.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_inverse_kernel_matches_ref(m, l, j, dtype, seed):
    d = _rand((l, j), dtype, seed)
    chat = _rand((m, l), dtype, seed + 2)
    got = dwt_pallas.dwt_contract_inverse(d, chat)
    want = ref.dwt_contract_inverse_ref(d, chat)
    tol = 1e-12 if dtype == jnp.float64 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol * l)
    assert got.dtype == dtype


@pytest.mark.parametrize("b", [4, 8, 16])
def test_artifact_shapes_forward(b):
    """The exact shapes the AOT artifacts are compiled for."""
    d = _rand((b, 2 * b), jnp.float64, b)
    t = _rand((8, 2 * b), jnp.float64, b + 1)
    got = dwt_pallas.dwt_contract_forward(d, t)
    want = ref.dwt_contract_forward_ref(d, t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)
    assert got.shape == (8, b)


@pytest.mark.parametrize("b", [4, 8, 16])
def test_artifact_shapes_inverse(b):
    d = _rand((b, 2 * b), jnp.float64, b)
    chat = _rand((8, b), jnp.float64, b + 3)
    got = dwt_pallas.dwt_contract_inverse(d, chat)
    want = ref.dwt_contract_inverse_ref(d, chat)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)
    assert got.shape == (8, 2 * b)


def test_explicit_block_sizes():
    """Tiling must not change results (only the HBM→VMEM schedule)."""
    d = _rand((32, 16), jnp.float64, 0)
    t = _rand((8, 16), jnp.float64, 1)
    base = dwt_pallas.dwt_contract_forward(d, t, l_blk=32)
    for blk in [1, 2, 4, 8, 16]:
        tiled = dwt_pallas.dwt_contract_forward(d, t, l_blk=blk)
        np.testing.assert_allclose(np.asarray(tiled), np.asarray(base), atol=1e-13)
    chat = _rand((8, 32), jnp.float64, 2)
    base_i = dwt_pallas.dwt_contract_inverse(d, chat, l_blk=32)
    for blk in [1, 2, 4, 8, 16]:
        tiled = dwt_pallas.dwt_contract_inverse(d, chat, l_blk=blk)
        np.testing.assert_allclose(np.asarray(tiled), np.asarray(base_i), atol=1e-13)


def test_zero_padding_is_exact():
    """Padded (zero) rows and members yield exactly-zero outputs — the
    contract the fixed-shape artifacts rely on."""
    b = 8
    l0 = 5  # pretend cluster with l0=5: rows 0..4 zero
    d = np.array(_rand((b, 2 * b), jnp.float64, 9))
    d[:l0, :] = 0.0
    t = np.array(_rand((8, 2 * b), jnp.float64, 10))
    t[3:, :] = 0.0  # only 3 real members
    c = np.asarray(dwt_pallas.dwt_contract_forward(jnp.asarray(d), jnp.asarray(t)))
    assert np.all(c[:, :l0] == 0.0), "padded degrees must be exactly zero"
    assert np.all(c[3:, :] == 0.0), "padded members must be exactly zero"


def test_kernel_is_linear():
    d = _rand((12, 10), jnp.float64, 4)
    t1 = _rand((8, 10), jnp.float64, 5)
    t2 = _rand((8, 10), jnp.float64, 6)
    lhs = dwt_pallas.dwt_contract_forward(d, t1 + 2.0 * t2)
    rhs = dwt_pallas.dwt_contract_forward(d, t1) + 2.0 * dwt_pallas.dwt_contract_forward(d, t2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-12)

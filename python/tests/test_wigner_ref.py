"""Sanity checks on the python Wigner/quadrature reference (which must
mirror the rust implementation exactly — same seeds, same recurrence)."""

import math

import numpy as np
import pytest

from compile.kernels import ref


def test_legendre_special_case():
    for beta in [0.4, 1.3, 2.2]:
        col = ref.wigner_d_column(4, 0, 0, beta)
        x = math.cos(beta)
        np.testing.assert_allclose(
            col, [1.0, x, 1.5 * x * x - 0.5, 2.5 * x**3 - 1.5 * x], atol=1e-13
        )


def test_d1_entries():
    for beta in [0.3, 1.0, 2.5]:
        assert ref.wigner_d_column(2, 1, 0, beta)[1] == pytest.approx(
            math.sin(beta) / math.sqrt(2), abs=1e-13
        )
        assert ref.wigner_d_column(2, 1, 1, beta)[1] == pytest.approx(
            (1 + math.cos(beta)) / 2, abs=1e-13
        )
        assert ref.wigner_d_column(2, 1, -1, beta)[1] == pytest.approx(
            (1 - math.cos(beta)) / 2, abs=1e-13
        )


def test_symmetries():
    rng = np.random.default_rng(0)
    for _ in range(50):
        l = int(rng.integers(1, 10))
        m = int(rng.integers(-l, l + 1))
        mp = int(rng.integers(-l, l + 1))
        beta = float(rng.uniform(0.05, math.pi - 0.05))
        b = l + 1
        d = ref.wigner_d_column(b, m, mp, beta)[l]
        s = -1.0 if (m - mp) % 2 else 1.0
        assert ref.wigner_d_column(b, -m, -mp, beta)[l] * s == pytest.approx(d, abs=1e-11)
        assert ref.wigner_d_column(b, mp, m, beta)[l] * s == pytest.approx(d, abs=1e-11)
        assert ref.wigner_d_column(b, -mp, -m, beta)[l] == pytest.approx(d, abs=1e-11)


def test_quadrature_orthogonality():
    """Sum_j w(j) d(l)d(l') = 2pi/(B(2l+1)) delta — the sampling theorem's
    engine, and the cross-language convention lock with rust."""
    b = 6
    w = ref.quadrature_weights(b)
    betas = ref.grid_betas(b)
    for m, mp in [(0, 0), (2, 1), (3, -2)]:
        l0 = max(abs(m), abs(mp))
        cols = np.stack([ref.wigner_d_column(b, m, mp, bj) for bj in betas])  # [j, l]
        for l1 in range(l0, b):
            for l2 in range(l0, b):
                dot = float(np.sum(w * cols[:, l1] * cols[:, l2]))
                want = 2 * math.pi / (b * (2 * l1 + 1)) if l1 == l2 else 0.0
                assert dot == pytest.approx(want, abs=1e-12)


def test_weights_sum():
    for b in [2, 8, 16]:
        assert ref.quadrature_weights(b).sum() == pytest.approx(
            2 * math.pi / b, rel=1e-12
        )


def test_wigner_rows_layout():
    b = 5
    rows = ref.wigner_rows(b, 3, 1)
    assert rows.shape == (b, 2 * b)
    assert np.all(rows[:3, :] == 0.0), "degrees below l0 are zero rows"
    assert np.any(rows[3, :] != 0.0)

"""The AOT path: lowering must produce loadable HLO text with the right
entry signature (the rust runtime parses these files)."""

import json

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def lowered_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entry = aot.lower_bandwidth(4, out)
    (out / "manifest.json").write_text(json.dumps({"bandwidths": {"4": entry}}))
    return out


def test_files_exist(lowered_dir):
    assert (lowered_dir / "dwt_fwd_b4.hlo.txt").exists()
    assert (lowered_dir / "dwt_inv_b4.hlo.txt").exists()


def test_hlo_text_structure(lowered_dir):
    text = (lowered_dir / "dwt_fwd_b4.hlo.txt").read_text()
    assert text.startswith("HloModule"), "must be HLO text, not a proto"
    # Entry computation takes f64[4,8], f64[8,8], f64[8,8] and returns a
    # tuple of two f64[8,4].
    assert "f64[4,8]" in text
    assert "f64[8,8]" in text
    assert "(f64[8,4]{1,0}, f64[8,4]{1,0})" in text


def test_inverse_hlo_shapes(lowered_dir):
    text = (lowered_dir / "dwt_inv_b4.hlo.txt").read_text()
    assert text.startswith("HloModule")
    assert "f64[8,4]" in text  # chat inputs
    assert "(f64[8,8]{1,0}, f64[8,8]{1,0})" in text  # member j-vector tuple


def test_no_custom_calls(lowered_dir):
    """interpret=True must lower to plain HLO the CPU client can run —
    a Mosaic custom-call here would break the rust runtime."""
    for name in ["dwt_fwd_b4.hlo.txt", "dwt_inv_b4.hlo.txt"]:
        text = (lowered_dir / name).read_text()
        assert "custom-call" not in text, f"{name} contains a custom-call"


def test_manifest_contents(lowered_dir):
    manifest = json.loads((lowered_dir / "manifest.json").read_text())
    entry = manifest["bandwidths"]["4"]
    assert entry["l_dim"] == 4
    assert entry["j_dim"] == 8
    assert entry["member_pad"] == model.MEMBER_PAD

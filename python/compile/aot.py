"""AOT-lower the L2 DWT graphs to HLO **text** artifacts for the rust
runtime (``rust/src/runtime``).

Interchange format is HLO text, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the pinned
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage (normally via ``make artifacts``):

    python -m compile.aot --out-dir ../artifacts --bandwidths "4 8 16 32"

Emits per bandwidth:
    dwt_fwd_b{B}.hlo.txt   — forward contraction (see compile.model)
    dwt_inv_b{B}.hlo.txt   — inverse contraction
and a ``manifest.json`` describing shapes for the rust loader.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    rust side unwraps a single tuple output)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bandwidth(b: int, out_dir: pathlib.Path) -> dict:
    """Lower both artifacts for one bandwidth; returns manifest entries."""
    fwd = jax.jit(model.dwt_forward_stage).lower(*model.forward_shapes(b))
    inv = jax.jit(model.dwt_inverse_stage).lower(*model.inverse_shapes(b))
    fwd_name = f"dwt_fwd_b{b}.hlo.txt"
    inv_name = f"dwt_inv_b{b}.hlo.txt"
    (out_dir / fwd_name).write_text(to_hlo_text(fwd))
    (out_dir / inv_name).write_text(to_hlo_text(inv))
    return {
        "forward": fwd_name,
        "inverse": inv_name,
        "member_pad": model.MEMBER_PAD,
        "l_dim": b,
        "j_dim": 2 * b,
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--bandwidths",
        default="4 8 16 32",
        help="space- or comma-separated bandwidth list",
    )
    args = parser.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    bandwidths = [int(tok) for tok in args.bandwidths.replace(",", " ").split()]

    manifest = {"dtype": "f64", "bandwidths": {}}
    for b in bandwidths:
        manifest["bandwidths"][str(b)] = lower_bandwidth(b, out_dir)
        print(f"lowered bandwidth {b}")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    print(f"wrote {len(bandwidths)}x2 artifacts + manifest to {out_dir}")


if __name__ == "__main__":
    main()

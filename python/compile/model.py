"""Layer-2: the complex DWT stage as a JAX graph over the Pallas kernels.

The rust coordinator works in complex arithmetic with the real Wigner
rows; across the PJRT boundary the complex member vectors travel as
separate re/im planes, and the contraction is two real matmuls sharing
the same ``d`` panel. This module assembles those graphs — these are the
functions AOT-lowered by :mod:`compile.aot`, one pair per bandwidth:

* ``dwt_forward_stage(d, t_re, t_im)   -> (c_re, c_im)``  with
  ``c[m, l] = sum_j d[l, j] * t[m, j]``
* ``dwt_inverse_stage(d, c_re, c_im)   -> (s_re, s_im)``  with
  ``s[m, j] = sum_l d[l, j] * c[m, l]``

Shapes are fixed per artifact: d is [B, 2B] (rows below the cluster's l0
zero-padded), the member axis is padded to MEMBER_PAD = 8 (the maximum
symmetry-cluster size). Zero padding is exact: padded rows/members
produce zero outputs which the coordinator ignores.

Signs, reflections, quadrature weights and the V(l) scale stay in rust —
the artifact is a pure contraction, so one compiled executable serves
every cluster of its bandwidth.
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import dwt_pallas  # noqa: E402

#: Maximum symmetry-cluster size (paper §3: groups of eight or less).
MEMBER_PAD = 8


def dwt_forward_stage(d: jnp.ndarray, t_re: jnp.ndarray, t_im: jnp.ndarray):
    """Complex forward DWT contraction as two real Pallas matmuls."""
    c_re = dwt_pallas.dwt_contract_forward(d, t_re)
    c_im = dwt_pallas.dwt_contract_forward(d, t_im)
    return c_re, c_im


def dwt_inverse_stage(d: jnp.ndarray, c_re: jnp.ndarray, c_im: jnp.ndarray):
    """Complex inverse DWT contraction as two real Pallas matmuls."""
    s_re = dwt_pallas.dwt_contract_inverse(d, c_re)
    s_im = dwt_pallas.dwt_contract_inverse(d, c_im)
    return s_re, s_im


def forward_shapes(b: int):
    """Example-input shapes for the forward artifact of bandwidth b."""
    f8 = jnp.float64
    return (
        jax.ShapeDtypeStruct((b, 2 * b), f8),          # d rows
        jax.ShapeDtypeStruct((MEMBER_PAD, 2 * b), f8),  # t re
        jax.ShapeDtypeStruct((MEMBER_PAD, 2 * b), f8),  # t im
    )


def inverse_shapes(b: int):
    """Example-input shapes for the inverse artifact of bandwidth b."""
    f8 = jnp.float64
    return (
        jax.ShapeDtypeStruct((b, 2 * b), f8),          # d rows
        jax.ShapeDtypeStruct((MEMBER_PAD, b), f8),      # chat re
        jax.ShapeDtypeStruct((MEMBER_PAD, b), f8),      # chat im
    )

"""Layer-1: the DWT contraction as Pallas kernels.

The FSOFT hot spot is, per symmetry cluster, a small dense contraction
between the base Wigner rows ``d[L, J]`` (J = 2B beta nodes) and the
cluster's member vectors:

* forward:  ``c[m, l] = sum_j d[l, j] * t[m, j]``   (t = weighted samples)
* inverse:  ``s[m, j] = sum_l d[l, j] * chat[m, l]``

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets a
64-core CPU with OpenMP, so there is no thread-block structure to port.
For the TPU formulation we express the contraction as an MXU-shaped
matmul and let BlockSpec stage HBM→VMEM panels of ``d``:

* the L axis is tiled (``L_BLK`` rows of d per grid step) — each tile of
  ``d`` plus the full member panel fits comfortably in VMEM
  (L_BLK·J + M·J + M·L_BLK doubles; ~0.3 MB at B = 512, L_BLK = 64);
* the member axis M (≤ 8, padded) rides along fully resident — it is the
  tiny dimension of the systolic matmul;
* accumulation happens in the kernel's output ref, one (M, L_BLK) panel
  per grid step — no cross-step carries, so no scratch semaphores.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; on-TPU behaviour is estimated in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(d_ref, t_ref, o_ref):
    """One grid step: o[M, L_BLK] = t[M, J] @ d[L_BLK, J]^T."""
    o_ref[...] = jax.lax.dot_general(
        t_ref[...],
        d_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


def _inv_kernel(d_ref, c_ref, o_ref):
    """One grid step: o[M, J] += chat[M, L_BLK] @ d[L_BLK, J].

    The L axis is the *contraction* axis here, so each grid step adds one
    partial product into the output panel.
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        c_ref[...],
        d_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


def _pick_block(n: int, target: int = 64) -> int:
    """Largest divisor of n not exceeding target (keeps the grid exact)."""
    best = 1
    for cand in range(1, min(n, target) + 1):
        if n % cand == 0:
            best = cand
    return best


@functools.partial(jax.jit, static_argnames=("l_blk",))
def dwt_contract_forward(d: jnp.ndarray, t: jnp.ndarray, l_blk: int | None = None):
    """c[m, l] = sum_j d[l, j] * t[m, j] via the Pallas kernel.

    d: [L, J] float; t: [M, J] float. Returns [M, L].
    """
    l, j = d.shape
    m, j2 = t.shape
    assert j == j2, f"J mismatch: {j} vs {j2}"
    blk = l_blk if l_blk is not None else _pick_block(l)
    grid = (l // blk,)
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, j), lambda i: (i, 0)),   # d panel: HBM→VMEM per step
            pl.BlockSpec((m, j), lambda i: (0, 0)),     # t resident across steps
        ],
        out_specs=pl.BlockSpec((m, blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, l), d.dtype),
        interpret=True,
    )(d, t)


@functools.partial(jax.jit, static_argnames=("l_blk",))
def dwt_contract_inverse(d: jnp.ndarray, chat: jnp.ndarray, l_blk: int | None = None):
    """s[m, j] = sum_l d[l, j] * chat[m, l] via the Pallas kernel.

    d: [L, J] float; chat: [M, L] float. Returns [M, J].
    """
    l, j = d.shape
    m, l2 = chat.shape
    assert l == l2, f"L mismatch: {l} vs {l2}"
    blk = l_blk if l_blk is not None else _pick_block(l)
    grid = (l // blk,)
    return pl.pallas_call(
        _inv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, j), lambda i: (i, 0)),   # d panel per step
            pl.BlockSpec((m, blk), lambda i: (0, i)),   # matching chat panel
        ],
        out_specs=pl.BlockSpec((m, j), lambda i: (0, 0)),  # accumulated output
        out_shape=jax.ShapeDtypeStruct((m, j), d.dtype),
        interpret=True,
    )(d, chat)

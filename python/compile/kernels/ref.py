"""Pure-jnp / numpy reference oracles for the Pallas DWT kernels.

This module is the python-side ground truth:

* ``dwt_contract_forward_ref`` / ``dwt_contract_inverse_ref`` — the exact
  einsum the Pallas kernels must reproduce (the DWT's inner contraction;
  signs, reflections, quadrature weights and the V(l) scale all live in
  the rust coordinator, so the kernel is a pure contraction).
* ``wigner_d_column`` — the paper's seed + three-term recurrence
  (Eq. 2), mirroring ``rust/src/so3/wigner.rs``; used to build realistic
  kernel inputs and to cross-check the rust implementation's convention.
* ``quadrature_weights`` — paper Eq. 6.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np


def dwt_contract_forward_ref(d: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """c[m, l] = sum_j d[l, j] * t[m, j]."""
    return jnp.einsum("lj,mj->ml", d, t)


def dwt_contract_inverse_ref(d: jnp.ndarray, chat: jnp.ndarray) -> jnp.ndarray:
    """s[m, j] = sum_l d[l, j] * chat[m, l]."""
    return jnp.einsum("lj,ml->mj", d, chat)


# ---------------------------------------------------------------------------
# Wigner-d reference (numpy, mirrors the rust implementation)
# ---------------------------------------------------------------------------


def _reduce_orders(m: int, mp: int) -> tuple[int, int, float]:
    """Reduce to the canonical domain m >= |m'| >= 0; returns sign."""
    sign = 1.0
    if abs(mp) > abs(m):
        m, mp = -mp, -m  # d(l,m,m') = d(l,-m',-m)
    if m < 0:
        sign = -1.0 if (m - mp) % 2 else 1.0  # (-1)^{m-m'}
        m, mp = -m, -mp
    return m, mp, sign


def _seed(m: int, mp: int, beta: float) -> float:
    """Log-domain seed d(m, m, m'; beta) for m >= |m'|."""
    if m == 0:
        return 1.0
    c, s = math.cos(beta / 2), math.sin(beta / 2)
    ln = 0.5 * (
        math.lgamma(2 * m + 1) - math.lgamma(m + mp + 1) - math.lgamma(m - mp + 1)
    )
    ln += (m + mp) * math.log(c) + (m - mp) * math.log(s)
    return math.exp(ln)


def wigner_d_column(b: int, m: int, mp: int, beta: float) -> np.ndarray:
    """d(l, m, m'; beta) for l = 0..b-1 (zeros below l0)."""
    out = np.zeros(b)
    rm, rmp, sign = _reduce_orders(m, mp)
    l0 = max(rm, abs(rmp))
    if l0 >= b:
        return out
    x = math.cos(beta)
    d_cur = sign * _seed(rm, rmp, beta)
    d_prev = 0.0
    for l in range(l0, b):
        out[l] = d_cur
        if l + 1 >= b:
            break
        if l == 0:
            d_prev, d_cur = d_cur, x * d_cur
        else:
            lf = float(l)
            l1 = lf + 1.0
            norm = math.sqrt((l1 * l1 - rm * rm) * (l1 * l1 - rmp * rmp))
            a1 = (2 * lf + 1) * l1 / norm
            a2 = -(2 * lf + 1) * (rm * rmp) / (lf * norm)
            a3 = l1 / lf * math.sqrt((lf * lf - rm * rm) * (lf * lf - rmp * rmp)) / norm
            d_prev, d_cur = d_cur, (a1 * x + a2) * d_cur - a3 * d_prev
    return out


def grid_betas(b: int) -> np.ndarray:
    """The K&R beta nodes: (2j+1)pi/4B, j = 0..2B-1."""
    return np.array([(2 * j + 1) * math.pi / (4 * b) for j in range(2 * b)])


def quadrature_weights(b: int) -> np.ndarray:
    """Paper Eq. 6."""
    betas = grid_betas(b)
    w = np.zeros(2 * b)
    for j, bj in enumerate(betas):
        acc = sum(math.sin((2 * i + 1) * bj) / (2 * i + 1) for i in range(b))
        w[j] = 2 * math.pi * math.sin(bj) / (b * b) * acc
    return w


def wigner_rows(b: int, m: int, mp: int) -> np.ndarray:
    """Dense base rows d[l, j] for l = 0..b-1 over all beta nodes
    (zero rows below l0) — the layout the AOT artifact consumes."""
    betas = grid_betas(b)
    rows = np.zeros((b, 2 * b))
    for j, bj in enumerate(betas):
        rows[:, j] = wigner_d_column(b, m, mp, bj)
    return rows

//! Spectral processing on SO(3): denoise a function on the rotation
//! group by low-pass filtering its SO(3) Fourier spectrum.
//!
//! The signal is band-limited to degrees l < B/2 (smooth orientation
//! distributions — e.g. crystallographic texture or a robot-pose belief —
//! live at low degree). The corruption adds broad-band noise across all
//! degrees. One FSOFT, a degree cutoff, and one iFSOFT remove the
//! out-of-band noise exactly and leave only the in-band part — the
//! classical projection filter, made practical by fast transforms.
//!
//! ```sh
//! cargo run --release --example spectral_filtering
//! ```

use so3ft::prng::Xoshiro256;
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::so3::sampling::So3Grid;
use so3ft::transform::So3Plan;
use so3ft::Complex64;

const B: usize = 16;
const CUT: usize = B / 2;

fn main() -> so3ft::Result<()> {
    let fft = So3Plan::builder(B).threads(2).build()?;

    // Ground truth: smooth spectrum, energy only below the cutoff.
    let mut rng = Xoshiro256::seed_from_u64(31);
    let mut truth = So3Coeffs::zeros(B);
    for l in 0..CUT {
        let li = l as i64;
        let scale = (-(l as f64) / 2.0).exp();
        for m in -li..=li {
            for mp in -li..=li {
                *truth.at_mut(l, m, mp) =
                    Complex64::new(rng.next_signed(), rng.next_signed()).scale(scale);
            }
        }
    }
    let clean = fft.inverse(&truth)?;

    // Broad-band corruption: noise coefficients at *every* degree.
    let sigma = 0.02;
    let mut noise = So3Coeffs::zeros(B);
    for v in noise.as_mut_slice().iter_mut() {
        *v = Complex64::new(rng.next_signed(), rng.next_signed()).scale(sigma);
    }
    let noise_grid = fft.inverse(&noise)?;
    let mut noisy = clean.clone();
    for (v, n) in noisy.as_mut_slice().iter_mut().zip(noise_grid.as_slice()) {
        *v += *n;
    }

    let err_before = rms_error(&noisy, &clean);

    // Analyze, cut at l >= CUT, synthesize.
    let spectrum = fft.forward(&noisy)?;
    let mut filtered = So3Coeffs::zeros(B);
    for (l, m, mp, v) in spectrum.iter() {
        if l < CUT {
            *filtered.at_mut(l, m, mp) = v;
        }
    }
    let denoised = fft.inverse(&filtered)?;
    let err_after = rms_error(&denoised, &clean);

    // Out-of-band noise energy dominates (most (l,m,m') triples live at
    // high degree), so the projection should remove most of the error.
    println!("rms error vs clean signal (B = {B}, cutoff l < {CUT}):");
    println!("  noisy:    {err_before:.5}");
    println!("  filtered: {err_after:.5}");
    println!("  improvement: {:.2}x", err_before / err_after);
    assert!(
        err_after < 0.55 * err_before,
        "low-pass projection should remove the out-of-band noise energy \
         (before {err_before}, after {err_after})"
    );
    println!("OK");
    Ok(())
}

fn rms_error(a: &So3Grid, b: &So3Grid) -> f64 {
    let n = a.as_slice().len() as f64;
    (a.as_slice()
        .iter()
        .zip(b.as_slice())
        .map(|(x, y)| (*x - *y).norm_sqr())
        .sum::<f64>()
        / n)
        .sqrt()
}

//! Fast rotational matching — the application family from the paper's
//! introduction (EM density fitting, molecular replacement, docking,
//! spherical image registration).
//!
//! A synthetic "molecule" is modeled as a band-limited density on the
//! sphere (a sum of Gaussian-like lobes). We rotate it by a hidden
//! rotation, add noise, and recover the rotation with one iFSOFT over
//! the full (2B)³ rotation grid.
//!
//! ```sh
//! cargo run --release --example rotational_matching
//! ```

use so3ft::apps::matching;
use so3ft::apps::sphere::{analysis, sphere_angles, SphCoeffs, SphGrid};
use so3ft::prng::Xoshiro256;
use so3ft::so3::rotation::{EulerZyz, Rotation};
use so3ft::transform::So3Plan;
use so3ft::Complex64;

const B: usize = 16;

/// Synthetic spherical density: a few smooth lobes at random directions.
fn synthetic_molecule(seed: u64) -> SphCoeffs {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let n = 2 * B;
    let (thetas, phis) = sphere_angles(B).unwrap();
    // Lobe centers and widths.
    let lobes: Vec<([f64; 3], f64, f64)> = (0..6)
        .map(|_| {
            let z: f64 = rng.next_signed();
            let phi = rng.next_f64() * std::f64::consts::TAU;
            let s = (1.0 - z * z).sqrt();
            (
                [s * phi.cos(), s * phi.sin(), z],
                3.0 + 5.0 * rng.next_f64(),  // sharpness
                0.5 + rng.next_f64(),        // weight
            )
        })
        .collect();
    let mut grid = SphGrid::zeros(B);
    for (j, &theta) in thetas.iter().enumerate() {
        for (k, &phi) in phis.iter().enumerate() {
            let v = [
                theta.sin() * phi.cos(),
                theta.sin() * phi.sin(),
                theta.cos(),
            ];
            let mut val = 0.0;
            for (c, sharp, w) in &lobes {
                let dot = v[0] * c[0] + v[1] * c[1] + v[2] * c[2];
                val += w * (sharp * (dot - 1.0)).exp();
            }
            grid.data[j * n + k] = Complex64::new(val, 0.0);
        }
    }
    // Band-limit by analysis (the projection onto H_B on the sphere).
    analysis(&grid).unwrap()
}

fn main() -> so3ft::Result<()> {
    let f = synthetic_molecule(7);

    // Hidden rotation (not grid-aligned: tests real-world recovery).
    let hidden = EulerZyz::new(2.135, 1.04, 5.58);
    let mut g = f.rotate(hidden);

    // Measurement noise on the rotated copy's coefficients.
    let mut rng = Xoshiro256::seed_from_u64(99);
    for l in 0..B {
        let li = l as i64;
        for m in -li..=li {
            let noise = Complex64::new(rng.next_signed(), rng.next_signed()).scale(0.01);
            *g.at_mut(l, m) += noise;
        }
    }

    println!("searching {} rotations with one iFSOFT (B = {B})...", (2 * B).pow(3));
    let fft = So3Plan::builder(B).threads(4).build()?;
    let t0 = std::time::Instant::now();
    let result = matching::match_rotation(&fft, &f, &g)?;
    let dt = t0.elapsed();

    let r_hidden = Rotation::from_euler(hidden);
    let r_found = Rotation::from_euler(result.euler);
    let dist = r_hidden.angular_distance(&r_found);
    let cell = std::f64::consts::PI / B as f64;

    println!("hidden  rotation: α={:.4} β={:.4} γ={:.4}", hidden.alpha, hidden.beta, hidden.gamma);
    println!(
        "found   rotation: α={:.4} β={:.4} γ={:.4}  (peak {:.3}, {dt:?})",
        result.euler.alpha, result.euler.beta, result.euler.gamma, result.peak
    );
    println!(
        "angular distance: {:.4} rad  (grid cell ≈ {:.4} rad)",
        dist, cell
    );
    assert!(
        dist < 1.8 * cell,
        "matching failed: distance {dist} exceeds ~2 grid cells"
    );
    println!("OK — recovered within grid resolution despite noise");
    Ok(())
}

//! End-to-end driver (DESIGN.md §6): exercises the full system on a real
//! workload and reports the paper's headline metrics. Results are
//! recorded in EXPERIMENTS.md.
//!
//! Pipeline per bandwidth:
//!   1. random spectra (the paper's benchmark §4 workload),
//!   2. iFSOFT synthesis + FSOFT analysis (native rust path),
//!   3. roundtrip error (paper Table 1 metric),
//!   4. thread sweep on the real pool (this container has 1 core, so
//!      wall-clock parallel speedup is ≈ flat — printed for honesty),
//!   5. per-package profile → simulated 64-core Opteron-like speedup
//!      (paper Figs. 2-4 metric),
//!   6. if AOT artifacts exist for the bandwidth, the same transform
//!      through the PJRT/XLA DWT backend, validated against native,
//!   7. a DWT-stage engine sweep (matvec baseline vs the β-parity-folded
//!      engine vs Clenshaw, over both Wigner sources) — the
//!      `dwt_stage_*` records the bench-smoke gate pins,
//!   8. an FFT-stage engine sweep (split-radix panel vs radix-2
//!      gather/scatter baseline, single- and max-thread) at the large
//!      bandwidths the DWT can't reach in-process,
//!   9. a SIMD dispatch sweep (`simd = scalar` vs `simd = auto`) over
//!      the folded DWT and split-radix FFT stages — the `simd_*`
//!      records the bench-smoke gate pins, plus a `simd_detected`
//!      record naming the ISA runtime dispatch chose.
//!
//! Every run also emits a machine-readable **`BENCH_fft.json`**
//! (override the path with `SO3FT_BENCH_JSON`) carrying the per-stage
//! `StageStats` timings, bandwidths, thread counts, and the FFT-engine
//! comparison — the repo's tracked perf trajectory across PRs (see
//! docs/PERF.md).
//!
//! With `--large-b` the driver instead runs ONLY the large-bandwidth
//! sweep toward the paper's headline B=512: forward + inverse at
//! `SO3FT_LARGE_BS` (default `128 256 512`), single- vs
//! `SO3FT_LARGE_THREADS` threads, under the `SO3FT_LARGE_BUDGET_MB`
//! memory budget (`auto` | `unlimited` | MiB; tight budgets stream
//! Wigner degrees instead of materializing full tables). It emits
//! `large_b_forward` / `large_b_inverse` / `large_b_speedup` /
//! `large_b_peak_bytes` records — the peak-bytes record is gated in CI
//! against the full-materialization footprint, the speedup record is
//! informational.
//!
//! ```sh
//! cargo run --release --example e2e_benchmark
//! SO3FT_E2E_BS="8 16 32" cargo run --release --example e2e_benchmark
//! SO3FT_LARGE_BS=128 SO3FT_LARGE_BUDGET_MB=640 \
//!   cargo run --release --example e2e_benchmark -- --large-b
//! ```

use std::cell::RefCell;
use std::sync::Arc;
use std::time::Instant;

use so3ft::bench_util::{
    env_usize, env_usize_list, fmt_seconds, write_json_report, Samples, Table,
};
use so3ft::coordinator::StageStats;
use so3ft::fft::{ColumnPass, Complex64, Fft2, FftAlgo, FftPlan, Sign};
use so3ft::pool::{Schedule, WorkerPool};
use so3ft::prng::Xoshiro256;
use so3ft::simd::{detected_isa, SimdIsa, SimdPolicy};
use so3ft::util::SyncUnsafeSlice;
use so3ft::runtime::{ArtifactRegistry, XlaDwt};
use so3ft::simulator::cost::{measured_spec, TransformKind};
use so3ft::simulator::machine::MachineParams;
use so3ft::simulator::scaling::scaling_curve;
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::transform::So3Plan;
use so3ft::wisdom::{PlanRigor, WisdomStore};

/// One JSON record with the full per-stage breakdown of a transform.
fn stage_record(kind: &str, b: usize, threads: usize, engine: &str, s: &StageStats) -> String {
    format!(
        "{{\"kind\": \"{kind}\", \"b\": {b}, \"threads\": {threads}, \
         \"engine\": \"{engine}\", \"fft_s\": {:.6e}, \"transpose_s\": {:.6e}, \
         \"dwt_s\": {:.6e}, \"total_s\": {:.6e}, \"fft_fraction\": {:.4}}}",
        s.fft.as_secs_f64(),
        s.transpose.as_secs_f64(),
        s.dwt.as_secs_f64(),
        s.total.as_secs_f64(),
        s.fft_fraction(),
    )
}

thread_local! {
    /// Per-worker gather/scatter scratch (empty in panel mode). The
    /// sweep runs on a persistent pool, so this is allocated once per
    /// parked worker and reused across every sweep of the run.
    static SWEEP_SCRATCH: RefCell<Vec<Complex64>> = const { RefCell::new(Vec::new()) };
}

/// Wall time of one FFT-stage region: `n` β-slice 2-D FFTs of a shared
/// `n³` slab over the persistent worker pool — the exact shape (and
/// SAFETY argument) of the executor's stage-1/stage-3 parallel region,
/// on the same runtime the executor serves from (parked workers, no
/// OS-thread spawn in the timed window). The slab is allocated and
/// initialized by the caller, outside the timed window; callers rescale
/// it between sweeps (an unnormalized 2-D FFT grows the RMS magnitude
/// ×n per call), also untimed.
fn fft_stage_sweep(
    fft2: &Fft2,
    slab: &mut [Complex64],
    pool: &WorkerPool,
    threads: usize,
    sign: Sign,
) -> f64 {
    let n = fft2.len();
    assert_eq!(slab.len(), n * n * n, "slab must be n^3");
    let slen = fft2.scratch_len();
    let shared = SyncUnsafeSlice::new(slab);
    let t0 = Instant::now();
    pool.run_with(threads, n, Schedule::Dynamic { chunk: 1 }, |j| {
        // SAFETY: slice j is exclusive to this package (one package per
        // β-slice, disjoint slab ranges).
        let slice =
            unsafe { std::slice::from_raw_parts_mut(shared.ptr_at(j * n * n), n * n) };
        SWEEP_SCRATCH.with(|sc| {
            let mut scratch = sc.borrow_mut();
            if scratch.len() < slen {
                scratch.resize(slen, Complex64::zero());
            }
            fft2.process(slice, &mut scratch[..slen], sign);
        });
    });
    t0.elapsed().as_secs_f64()
}

/// The `--large-b` sweep: full transforms at the paper's headline
/// bandwidths under a [`so3ft::MemoryBudget`], reporting wall time,
/// thread speedup/efficiency, and ledger/RSS peak memory. Runs instead
/// of the regular driver (the regular sweeps would not fit alongside
/// the large-B workspaces in one process).
fn run_large_b() -> so3ft::Result<()> {
    use so3ft::bench_util::append_json_records;
    use so3ft::coordinator::{workspace_bytes, MemoryBudget};
    use so3ft::dwt::tables::{WignerStorage, WignerTables};
    use so3ft::so3::sampling::So3Grid;
    use so3ft::util::ledger;

    let bandwidths = env_usize_list("SO3FT_LARGE_BS", &[128, 256, 512]);
    let threads_hi = env_usize(
        "SO3FT_LARGE_THREADS",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
    )
    .max(1);
    let reps = env_usize("SO3FT_LARGE_REPS", 1).max(1);
    let budget = match std::env::var("SO3FT_LARGE_BUDGET_MB") {
        Ok(s) => MemoryBudget::parse(&s).ok_or_else(|| {
            so3ft::Error::Config(format!(
                "bad SO3FT_LARGE_BUDGET_MB {s:?} (auto|unlimited|bytes:N|MiB)"
            ))
        })?,
        Err(_) => MemoryBudget::Auto,
    };
    let mib = |x: usize| x as f64 / (1 << 20) as f64;

    println!("=== so3ft large-B sweep (paper headline B=512) ===");
    println!(
        "bandwidths: {bandwidths:?}  threads: 1 vs {threads_hi}  reps: {reps}  \
         budget: {budget}\n"
    );

    let mut records: Vec<String> = Vec::new();
    let mut table = Table::new(&[
        "B", "threads", "engine", "iFSOFT", "FSOFT", "speedup", "eff", "peak MiB", "rel err",
    ]);
    let thread_counts: Vec<usize> = if threads_hi > 1 { vec![1, threads_hi] } else { vec![1] };

    for &b in &bandwidths {
        let full_bytes = WignerTables::full_bytes(b) + workspace_bytes(b);
        // t1/tN inverse+forward totals for the speedup record.
        let mut totals = [f64::NAN; 2];
        let mut sweep_peak = 0usize;
        let mut engine = "precomputed";
        for (ti, &threads) in thread_counts.iter().enumerate() {
            let plan = So3Plan::builder(b)
                .threads(threads)
                .storage(WignerStorage::Precomputed)
                .memory_budget(budget)
                .allow_any_bandwidth()
                .build()?;
            let report = plan.memory_report();
            engine = if report.streamed { "streamed" } else { "precomputed" };
            if ti == 0 {
                println!(
                    "--- bandwidth {b}: tables {:.1} MiB (full {:.1} MiB), \
                     workspace {:.1} MiB, {engine} ---",
                    mib(report.table_bytes),
                    mib(report.table_bytes_full),
                    mib(report.workspace_bytes),
                );
            }
            let coeffs = So3Coeffs::random(b, 0xB16 + b as u64);
            let mut grid = So3Grid::zeros(b)?;
            let mut back = So3Coeffs::zeros(b);
            let mut ws = plan.make_workspace();
            let mut best_inv = f64::INFINITY;
            let mut best_fwd = f64::INFINITY;
            let mut peak = 0usize;
            for _ in 0..reps {
                let istats = plan.inverse_into(&coeffs, &mut grid, &mut ws)?;
                let fstats = plan.forward_into(&grid, &mut back, &mut ws)?;
                best_inv = best_inv.min(istats.total.as_secs_f64());
                best_fwd = best_fwd.min(fstats.total.as_secs_f64());
                peak = peak.max(istats.peak_bytes).max(fstats.peak_bytes);
            }
            let rel_err = coeffs.max_rel_error(&back);
            totals[ti] = best_inv + best_fwd;
            sweep_peak = sweep_peak.max(peak);
            for (kind, total_s) in
                [("large_b_inverse", best_inv), ("large_b_forward", best_fwd)]
            {
                records.push(format!(
                    "{{\"kind\": \"{kind}\", \"b\": {b}, \"threads\": {threads}, \
                     \"engine\": \"{engine}\", \"total_s\": {total_s:.6e}, \
                     \"peak_bytes\": {peak}}}"
                ));
            }
            let speedup = totals[0] / totals[ti];
            table.row(&[
                b.to_string(),
                threads.to_string(),
                engine.to_string(),
                fmt_seconds(best_inv),
                fmt_seconds(best_fwd),
                format!("{speedup:.2}x"),
                format!("{:.2}", speedup / threads as f64),
                format!("{:.1}", mib(peak)),
                // Printed, not asserted: large-B roundtrip accuracy is
                // tracked here and pinned by the tier-1 suite at small B.
                format!("{rel_err:.1e}"),
            ]);
            // Plan (and its tables) drop here so the ledger drains
            // between thread counts and bandwidths.
        }
        if thread_counts.len() > 1 {
            let speedup = totals[0] / totals[1];
            records.push(format!(
                "{{\"kind\": \"large_b_speedup\", \"b\": {b}, \"threads\": {threads_hi}, \
                 \"engine\": \"{engine}\", \"speedup\": {speedup:.3}, \
                 \"efficiency\": {:.3}}}",
                speedup / threads_hi as f64
            ));
        }
        let ratio = sweep_peak as f64 / full_bytes as f64;
        let rss = ledger::peak_rss_bytes()
            .map(|r| format!(", \"peak_rss_bytes\": {r}"))
            .unwrap_or_default();
        records.push(format!(
            "{{\"kind\": \"large_b_peak_bytes\", \"b\": {b}, \"threads\": {threads_hi}, \
             \"engine\": \"{engine}\", \"peak_bytes\": {sweep_peak}, \
             \"full_materialization_bytes\": {full_bytes}, \"ratio\": {ratio:.3}{rss}}}"
        ));
        println!(
            "  peak {:.1} MiB vs full materialization {:.1} MiB (ratio {ratio:.2})\n",
            mib(sweep_peak),
            mib(full_bytes),
        );
    }

    println!("=== summary ===");
    table.print();

    let json_path =
        std::env::var("SO3FT_BENCH_JSON").unwrap_or_else(|_| "BENCH_fft.json".to_string());
    let result = if std::path::Path::new(&json_path).exists() {
        append_json_records(&json_path, &records)
    } else {
        let meta = [
            ("bench", "\"BENCH_fft_large_b\"".to_string()),
            ("crate_version", format!("\"{}\"", env!("CARGO_PKG_VERSION"))),
            ("threads_max", threads_hi.to_string()),
            ("memory_budget", format!("\"{budget}\"")),
            (
                "note",
                "\"large_b_* records come from the --large-b sweep: full \
                 inverse+forward transforms under a MemoryBudget, best-of-reps \
                 wall time and ledger peak_bytes; large_b_peak_bytes compares \
                 the measured peak against the full-materialization footprint \
                 (Wigner tables + workspace)\""
                    .to_string(),
            ),
        ];
        write_json_report(&json_path, &meta, &records)
    };
    match result {
        Ok(()) => println!("\nwrote {} ({} records)", json_path, records.len()),
        Err(e) => eprintln!("\nWARNING: could not write {json_path}: {e}"),
    }
    Ok(())
}

fn main() -> so3ft::Result<()> {
    if std::env::args().any(|a| a == "--large-b") {
        return run_large_b();
    }
    let bandwidths = env_usize_list("SO3FT_E2E_BS", &[8, 16, 32]);
    let params = MachineParams::opteron_like();
    let registry = ArtifactRegistry::default_location();
    let mut records: Vec<String> = Vec::new();

    println!("=== so3ft end-to-end benchmark ===");
    println!("bandwidths: {bandwidths:?}\n");

    let mut summary = Table::new(&[
        "B",
        "seq iFSOFT",
        "seq FSOFT",
        "abs err",
        "rel err",
        "sim S(8)",
        "sim S(64)",
        "xla backend",
    ]);

    for &b in &bandwidths {
        println!("--- bandwidth {b} ---");
        let coeffs = So3Coeffs::random(b, 7777);

        // Sequential reference run (the paper's speedup baseline).
        // (`allow_any_bandwidth`: the env override may name non-powers
        // of two, served by the Bluestein fallback.)
        let seq = So3Plan::builder(b)
            .threads(1)
            .allow_any_bandwidth()
            .build()?;
        let (grid, inv_stats) = seq.inverse_with_stats(&coeffs)?;
        let (back, fwd_stats) = seq.forward_with_stats(&grid)?;
        records.push(stage_record("transform_inverse", b, 1, "split_radix", &inv_stats));
        records.push(stage_record("transform_forward", b, 1, "split_radix", &fwd_stats));
        let abs_err = coeffs.max_abs_error(&back);
        let rel_err = coeffs.max_rel_error(&back);
        println!(
            "  sequential: iFSOFT {} / FSOFT {}  (fwd fft fraction {:.1}%)",
            fmt_seconds(inv_stats.total.as_secs_f64()),
            fmt_seconds(fwd_stats.total.as_secs_f64()),
            100.0 * fwd_stats.fft_fraction()
        );
        println!("  roundtrip:  abs {abs_err:.2e}, rel {rel_err:.2e}");

        // Real-pool thread sweep (honest: 1 physical core here).
        print!("  real pool wall-clock (1 physical core): ");
        for threads in [1usize, 2, 4] {
            let fft = So3Plan::builder(b)
                .threads(threads)
                .allow_any_bandwidth()
                .build()?;
            let t0 = std::time::Instant::now();
            let _ = fft.forward(&grid)?;
            print!("t{threads}={} ", fmt_seconds(t0.elapsed().as_secs_f64()));
        }
        println!();

        // Simulated multicore scaling from the measured per-package
        // profile (the documented hardware substitution).
        let spec_f = measured_spec(b, TransformKind::Forward)?;
        let curve = scaling_curve(&spec_f, &[1, 8, 64], &params);
        let s8 = curve[1].speedup;
        let s64 = curve[2].speedup;
        println!(
            "  simulated Opteron-like: S(8) = {s8:.2}, S(64) = {s64:.2} \
             (paper B=128..512 fwd: ~29.6-36.9 at 64 cores)"
        );

        // XLA/PJRT offload path, when artifacts exist and the backend is
        // compiled in (without the `xla` feature the load reports a
        // runtime error — treated as "unavailable", not a failure).
        let xla_status = if registry.available().contains(&b) {
            match XlaDwt::load(registry.dir(), b) {
                Ok(xla) => {
                    let off = So3Plan::builder(b)
                        .offload(Arc::new(xla))
                        .allow_any_bandwidth()
                        .build()?;
                    let t0 = std::time::Instant::now();
                    let c_xla = off.forward(&grid)?;
                    let dt = t0.elapsed();
                    let dev = back.max_abs_error(&c_xla);
                    println!(
                        "  xla offload: forward {} , |native - xla| = {dev:.2e}",
                        fmt_seconds(dt.as_secs_f64())
                    );
                    assert!(dev < 1e-12, "xla backend diverged from native");
                    format!("ok ({dev:.1e})")
                }
                Err(e) => {
                    // With the xla feature compiled in, a load failure is
                    // a real artifact/compile regression — propagate it.
                    if cfg!(feature = "xla") {
                        return Err(e);
                    }
                    println!("  xla offload unavailable: {e}");
                    "n/a".to_string()
                }
            }
        } else {
            println!("  xla offload: no artifacts for b={b} (run `make artifacts`)");
            "n/a".to_string()
        };

        summary.row(&[
            b.to_string(),
            fmt_seconds(inv_stats.total.as_secs_f64()),
            fmt_seconds(fwd_stats.total.as_secs_f64()),
            format!("{abs_err:.1e}"),
            format!("{rel_err:.1e}"),
            format!("{s8:.2}"),
            format!("{s64:.2}"),
            xla_status,
        ]);
        println!();
    }

    // DWT-stage engine sweep (ISSUE 4): matvec baseline vs the
    // β-parity-folded engine vs Clenshaw, over both Wigner sources, at
    // the e2e bandwidths. Sequential, so the per-stage `dwt_s` is the
    // kernel time the bench-smoke gate pins (dwt_stage_* records).
    println!("\n=== DWT stage: matvec vs matvec-folded vs clenshaw × wigner source ===");
    let mut dwt_table = Table::new(&["B", "engine", "fwd dwt", "inv dwt", "table MiB"]);
    for &b in &bandwidths {
        let coeffs = So3Coeffs::random(b, 4242);
        let mut folded_fwd = [0.0f64; 2];
        let mut folded_inv = [0.0f64; 2];
        for (engine, algorithm, storage) in [
            (
                "matvec+tables",
                so3ft::dwt::DwtAlgorithm::MatVec,
                so3ft::dwt::tables::WignerStorage::Precomputed,
            ),
            (
                "matvec-folded+tables",
                so3ft::dwt::DwtAlgorithm::MatVecFolded,
                so3ft::dwt::tables::WignerStorage::Precomputed,
            ),
            (
                "matvec+onthefly",
                so3ft::dwt::DwtAlgorithm::MatVec,
                so3ft::dwt::tables::WignerStorage::OnTheFly,
            ),
            (
                "matvec-folded+onthefly",
                so3ft::dwt::DwtAlgorithm::MatVecFolded,
                so3ft::dwt::tables::WignerStorage::OnTheFly,
            ),
            (
                "clenshaw",
                so3ft::dwt::DwtAlgorithm::Clenshaw,
                so3ft::dwt::tables::WignerStorage::OnTheFly,
            ),
        ] {
            let plan = So3Plan::builder(b)
                .algorithm(algorithm)
                .storage(storage)
                .allow_any_bandwidth()
                .build()?;
            let (grid, istats) = plan.inverse_with_stats(&coeffs)?;
            let (_, fstats) = plan.forward_with_stats(&grid)?;
            let fwd = fstats.dwt.as_secs_f64();
            let inv = istats.dwt.as_secs_f64();
            match engine {
                "matvec+tables" => {
                    folded_fwd[0] = fwd;
                    folded_inv[0] = inv;
                }
                "matvec-folded+tables" => {
                    folded_fwd[1] = fwd;
                    folded_inv[1] = inv;
                }
                _ => {}
            }
            for (kind, dwt_s, total_s) in [
                ("dwt_stage_forward", fwd, fstats.total.as_secs_f64()),
                ("dwt_stage_inverse", inv, istats.total.as_secs_f64()),
            ] {
                records.push(format!(
                    "{{\"kind\": \"{kind}\", \"b\": {b}, \"threads\": 1, \
                     \"engine\": \"{engine}\", \"dwt_s\": {dwt_s:.6e}, \
                     \"total_s\": {total_s:.6e}}}"
                ));
            }
            dwt_table.row(&[
                b.to_string(),
                engine.to_string(),
                fmt_seconds(fwd),
                fmt_seconds(inv),
                if plan.table_bytes() == 0 {
                    "-".into()
                } else {
                    format!("{:.2}", plan.table_bytes() as f64 / (1 << 20) as f64)
                },
            ]);
        }
        if folded_fwd[1] > 0.0 && folded_inv[1] > 0.0 {
            records.push(format!(
                "{{\"kind\": \"dwt_stage_speedup\", \"b\": {b}, \"threads\": 1, \
                 \"fwd_speedup\": {:.3}, \"inv_speedup\": {:.3}}}",
                folded_fwd[0] / folded_fwd[1],
                folded_inv[0] / folded_inv[1],
            ));
        }
    }
    dwt_table.print();

    // FFT-stage engine sweep: the per-β-slice 2-D FFT region (the shape
    // of the executor's stage 1/3) at bandwidths whose DWT would not fit
    // in this process, split-radix panel engine vs the radix-2
    // gather/scatter baseline, single- and max-thread.
    let fft_bs = env_usize_list("SO3FT_BENCH_FFT_BS", &[32, 64, 128]);
    let reps = env_usize("SO3FT_BENCH_FFT_REPS", 5).max(1);
    let max_threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let thread_counts: Vec<usize> = if max_threads > 1 {
        vec![1, max_threads]
    } else {
        vec![1]
    };
    // One persistent pool serves every sweep below (per-worker FFT
    // scratch stays pinned to the parked workers across sweeps).
    let sweep_pool = WorkerPool::new(max_threads).expect("sweep pool");

    println!("\n=== FFT stage: split-radix panel vs radix-2 gather/scatter ===");
    println!("({reps} reps, median; {max_threads} hardware threads)\n");
    let mut fft_table = Table::new(&["B", "threads", "split-radix", "radix2 base", "speedup"]);
    for &b in &fft_bs {
        let n = 2 * b;
        let split = Fft2::new(n, Arc::new(FftPlan::new(n)));
        let baseline = Fft2::with_column_pass(
            n,
            Arc::new(FftPlan::with_algo(n, FftAlgo::Radix2)),
            ColumnPass::GatherScatter,
        );
        // The full n³ grid slab (the executor's staging layout), built
        // once per bandwidth outside the timed windows. 256 MiB at
        // b = 128 — trim SO3FT_BENCH_FFT_BS on small machines.
        let mut rng = Xoshiro256::seed_from_u64(0xF0F0 + b as u64);
        let mut slab: Vec<Complex64> = (0..n * n * n)
            .map(|_| Complex64::new(rng.next_signed(), rng.next_signed()))
            .collect();
        let inv_n = 1.0 / n as f64;
        for &threads in &thread_counts {
            let mut stage_s = [0.0f64; 2];
            for (ei, fft2) in [&split, &baseline].into_iter().enumerate() {
                // Warm-up sweep (faults the slab in, exercises the pool).
                fft_stage_sweep(fft2, &mut slab, &sweep_pool, threads, Sign::Positive);
                let samples: Vec<f64> = (0..reps)
                    .map(|_| {
                        // Untimed rescale keeps magnitudes bounded
                        // (each sweep grows RMS by ×n).
                        for v in slab.iter_mut() {
                            *v = v.scale(inv_n);
                        }
                        fft_stage_sweep(fft2, &mut slab, &sweep_pool, threads, Sign::Positive)
                    })
                    .collect();
                stage_s[ei] = Samples { seconds: samples }.median();
                let engine = ["split_radix", "radix2_baseline"][ei];
                records.push(format!(
                    "{{\"kind\": \"fft_stage\", \"b\": {b}, \"n\": {n}, \
                     \"threads\": {threads}, \"engine\": \"{engine}\", \
                     \"stage_s\": {:.6e}, \"per_slice_s\": {:.6e}}}",
                    stage_s[ei],
                    stage_s[ei] / n as f64,
                ));
            }
            let speedup = stage_s[1] / stage_s[0];
            records.push(format!(
                "{{\"kind\": \"fft_stage_speedup\", \"b\": {b}, \
                 \"threads\": {threads}, \"speedup\": {speedup:.3}}}"
            ));
            fft_table.row(&[
                b.to_string(),
                threads.to_string(),
                fmt_seconds(stage_s[0]),
                fmt_seconds(stage_s[1]),
                format!("{speedup:.2}x"),
            ]);
        }
    }
    fft_table.print();

    // SIMD dispatch sweep (PR 7): the DWT and FFT stage regions under
    // `simd = scalar` vs `simd = auto`, single-threaded so the stage
    // times isolate the kernel difference rather than the schedule. The
    // bench-smoke gate pins these rows at the CI bandwidth
    // (SO3FT_BENCH_FFT_BS=16); `simd_detected` records which ISA runtime
    // dispatch chose, so a flat scalar-vs-auto delta on a scalar-only
    // host reads as expected rather than as a regression.
    let isa = detected_isa();
    records.push(format!(
        "{{\"kind\": \"simd_detected\", \"isa\": \"{}\"}}",
        isa.name()
    ));
    println!("\n=== SIMD dispatch: scalar baseline vs auto (detected: {}) ===", isa.name());
    let mut simd_table = Table::new(&["B", "policy", "fwd dwt", "inv dwt", "fft stage"]);
    for &b in &fft_bs {
        let n = 2 * b;
        // Precomputed half-tables outgrow the container above b = 32
        // (O(B^3) doubles); the on-the-fly source keeps the sweep's
        // footprint at the grid slabs only.
        let storage = if b <= 32 {
            so3ft::dwt::tables::WignerStorage::Precomputed
        } else {
            so3ft::dwt::tables::WignerStorage::OnTheFly
        };
        let coeffs = So3Coeffs::random(b, 0x51AD + b as u64);
        let mut rng = Xoshiro256::seed_from_u64(0x0D15 + b as u64);
        let mut slab: Vec<Complex64> = (0..n * n * n)
            .map(|_| Complex64::new(rng.next_signed(), rng.next_signed()))
            .collect();
        let inv_n = 1.0 / n as f64;
        for (engine, policy) in [("scalar", SimdPolicy::Scalar), ("auto", SimdPolicy::Auto)] {
            // DWT stage: a full sequential transform pair on the folded
            // engine; the plan and its grids drop before the FFT timing
            // below so the slab is the only live n^3 buffer.
            let (fwd_dwt_s, inv_dwt_s) = {
                let plan = So3Plan::builder(b)
                    .simd(policy)
                    .threads(1)
                    .algorithm(so3ft::dwt::DwtAlgorithm::MatVecFolded)
                    .storage(storage)
                    .allow_any_bandwidth()
                    .build()?;
                let (grid, istats) = plan.inverse_with_stats(&coeffs)?;
                let (_, fstats) = plan.forward_with_stats(&grid)?;
                for (kind, stats) in [
                    ("simd_dwt_stage_forward", &fstats),
                    ("simd_dwt_stage_inverse", &istats),
                ] {
                    records.push(format!(
                        "{{\"kind\": \"{kind}\", \"b\": {b}, \"threads\": 1, \
                         \"engine\": \"{engine}\", \"dwt_s\": {:.6e}, \
                         \"total_s\": {:.6e}}}",
                        stats.dwt.as_secs_f64(),
                        stats.total.as_secs_f64(),
                    ));
                }
                (fstats.dwt.as_secs_f64(), istats.dwt.as_secs_f64())
            };

            // FFT stage: same region shape as the engine sweep above,
            // with the split-radix plan pinned to this policy's ISA.
            let fft_isa = match policy {
                SimdPolicy::Scalar => SimdIsa::Scalar,
                _ => isa,
            };
            let fft2 = Fft2::new(
                n,
                Arc::new(FftPlan::with_algo_isa(n, FftAlgo::SplitRadix, fft_isa)),
            );
            fft_stage_sweep(&fft2, &mut slab, &sweep_pool, 1, Sign::Positive);
            let samples: Vec<f64> = (0..reps)
                .map(|_| {
                    for v in slab.iter_mut() {
                        *v = v.scale(inv_n);
                    }
                    fft_stage_sweep(&fft2, &mut slab, &sweep_pool, 1, Sign::Positive)
                })
                .collect();
            let stage_s = Samples { seconds: samples }.median();
            records.push(format!(
                "{{\"kind\": \"simd_fft_stage\", \"b\": {b}, \"n\": {n}, \
                 \"threads\": 1, \"engine\": \"{engine}\", \"fft_s\": {:.6e}, \
                 \"per_slice_s\": {:.6e}}}",
                stage_s,
                stage_s / n as f64,
            ));
            simd_table.row(&[
                b.to_string(),
                engine.to_string(),
                fmt_seconds(fwd_dwt_s),
                fmt_seconds(inv_dwt_s),
                fmt_seconds(stage_s),
            ]);
        }
    }
    simd_table.print();

    // Wisdom planner sweep (ISSUE 6): Estimate build vs a cold Measure
    // build (pays the search) vs a cached Measure build (store hit) at
    // every e2e bandwidth, against a fresh in-memory store per bandwidth
    // so cold/cached are well-defined regardless of prior runs. The
    // `plan_build` records' `overhead_s` (cached Measure minus Estimate)
    // is the number the CI gate pins: wisdom-on-hit must stay cheap.
    let wisdom_budget = std::time::Duration::from_millis(
        env_usize("SO3FT_BENCH_WISDOM_BUDGET_MS", 150) as u64,
    );
    println!("\n=== plan build: estimate vs measure (cold / cached wisdom) ===");
    let mut wisdom_table = Table::new(&["B", "estimate", "measure cold", "measure cached"]);
    for &b in &bandwidths {
        let store = WisdomStore::in_memory();
        let t0 = Instant::now();
        let _ = So3Plan::builder(b).allow_any_bandwidth().build()?;
        let estimate_s = t0.elapsed().as_secs_f64();
        let mut measured = [0.0f64; 2];
        for slot in measured.iter_mut() {
            let t0 = Instant::now();
            let plan = So3Plan::builder(b)
                .rigor(PlanRigor::Measure)
                .wisdom_store(std::sync::Arc::clone(&store))
                .wisdom_time_budget_ms(wisdom_budget.as_millis() as u64)
                .allow_any_bandwidth()
                .build()?;
            *slot = t0.elapsed().as_secs_f64();
            assert!(
                plan.wisdom().is_some_and(|w| w.choice.is_some()),
                "Measure build fell back to Estimate defaults at b={b}"
            );
        }
        let [cold_s, cached_s] = measured;
        assert_eq!(
            store.stats().measurements,
            1,
            "second Measure build must hit the store, not re-measure"
        );
        let overhead_s = (cached_s - estimate_s).max(0.0);
        records.push(format!(
            "{{\"kind\": \"plan_build\", \"b\": {b}, \"threads\": 1, \
             \"engine\": \"wisdom\", \"estimate_s\": {estimate_s:.6e}, \
             \"measure_cold_s\": {cold_s:.6e}, \"measure_cached_s\": {cached_s:.6e}, \
             \"overhead_s\": {overhead_s:.6e}}}"
        ));
        wisdom_table.row(&[
            b.to_string(),
            fmt_seconds(estimate_s),
            fmt_seconds(cold_s),
            fmt_seconds(cached_s),
        ]);
    }
    wisdom_table.print();

    let json_path =
        std::env::var("SO3FT_BENCH_JSON").unwrap_or_else(|_| "BENCH_fft.json".to_string());
    let meta = [
        ("bench", "\"BENCH_fft\"".to_string()),
        ("crate_version", format!("\"{}\"", env!("CARGO_PKG_VERSION"))),
        ("threads_max", max_threads.to_string()),
        ("reps", reps.to_string()),
        (
            "note",
            "\"fft_stage records time the per-beta-slice 2-D FFT region \
             (n slices of a shared n^3 slab, dynamic schedule; slab init \
             and rescales are untimed); transform_* records are full \
             sequential StageStats breakdowns; dwt_stage_* records carry \
             the sequential DWT-stage wall time per engine x wigner \
             source; plan_build records compare Estimate builds against \
             cold and store-cached Measure builds (overhead_s = cached \
             Measure minus Estimate, floored at 0); simd_* records \
             compare the scalar kernel baseline against auto SIMD \
             dispatch on the folded DWT and split-radix FFT stages \
             (simd_detected carries the ISA dispatch chose)\""
                .to_string(),
        ),
    ];
    match write_json_report(&json_path, &meta, &records) {
        Ok(()) => println!("\nwrote {} ({} records)", json_path, records.len()),
        Err(e) => eprintln!("\nWARNING: could not write {json_path}: {e}"),
    }

    println!("\n=== summary ===");
    summary.print();
    println!("\nall bandwidths passed roundtrip + backend validation");
    Ok(())
}

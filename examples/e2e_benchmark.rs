//! End-to-end driver (DESIGN.md §6): exercises the full system on a real
//! workload and reports the paper's headline metrics. Results are
//! recorded in EXPERIMENTS.md.
//!
//! Pipeline per bandwidth:
//!   1. random spectra (the paper's benchmark §4 workload),
//!   2. iFSOFT synthesis + FSOFT analysis (native rust path),
//!   3. roundtrip error (paper Table 1 metric),
//!   4. thread sweep on the real pool (this container has 1 core, so
//!      wall-clock parallel speedup is ≈ flat — printed for honesty),
//!   5. per-package profile → simulated 64-core Opteron-like speedup
//!      (paper Figs. 2-4 metric),
//!   6. if AOT artifacts exist for the bandwidth, the same transform
//!      through the PJRT/XLA DWT backend, validated against native.
//!
//! ```sh
//! cargo run --release --example e2e_benchmark
//! SO3FT_E2E_BS="8 16 32" cargo run --release --example e2e_benchmark
//! ```

use std::sync::Arc;

use so3ft::bench_util::{env_usize_list, fmt_seconds, Table};
use so3ft::runtime::{ArtifactRegistry, XlaDwt};
use so3ft::simulator::cost::{measured_spec, TransformKind};
use so3ft::simulator::machine::MachineParams;
use so3ft::simulator::scaling::scaling_curve;
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::transform::So3Plan;

fn main() -> so3ft::Result<()> {
    let bandwidths = env_usize_list("SO3FT_E2E_BS", &[8, 16, 32]);
    let params = MachineParams::opteron_like();
    let registry = ArtifactRegistry::default_location();

    println!("=== so3ft end-to-end benchmark ===");
    println!("bandwidths: {bandwidths:?}\n");

    let mut summary = Table::new(&[
        "B",
        "seq iFSOFT",
        "seq FSOFT",
        "abs err",
        "rel err",
        "sim S(8)",
        "sim S(64)",
        "xla backend",
    ]);

    for &b in &bandwidths {
        println!("--- bandwidth {b} ---");
        let coeffs = So3Coeffs::random(b, 7777);

        // Sequential reference run (the paper's speedup baseline).
        // (`allow_any_bandwidth`: the env override may name non-powers
        // of two, served by the Bluestein fallback.)
        let seq = So3Plan::builder(b)
            .threads(1)
            .allow_any_bandwidth()
            .build()?;
        let (grid, inv_stats) = seq.inverse_with_stats(&coeffs)?;
        let (back, fwd_stats) = seq.forward_with_stats(&grid)?;
        let abs_err = coeffs.max_abs_error(&back);
        let rel_err = coeffs.max_rel_error(&back);
        println!(
            "  sequential: iFSOFT {} / FSOFT {}  (fwd fft fraction {:.1}%)",
            fmt_seconds(inv_stats.total.as_secs_f64()),
            fmt_seconds(fwd_stats.total.as_secs_f64()),
            100.0 * fwd_stats.fft_fraction()
        );
        println!("  roundtrip:  abs {abs_err:.2e}, rel {rel_err:.2e}");

        // Real-pool thread sweep (honest: 1 physical core here).
        print!("  real pool wall-clock (1 physical core): ");
        for threads in [1usize, 2, 4] {
            let fft = So3Plan::builder(b)
                .threads(threads)
                .allow_any_bandwidth()
                .build()?;
            let t0 = std::time::Instant::now();
            let _ = fft.forward(&grid)?;
            print!("t{threads}={} ", fmt_seconds(t0.elapsed().as_secs_f64()));
        }
        println!();

        // Simulated multicore scaling from the measured per-package
        // profile (the documented hardware substitution).
        let spec_f = measured_spec(b, TransformKind::Forward)?;
        let curve = scaling_curve(&spec_f, &[1, 8, 64], &params);
        let s8 = curve[1].speedup;
        let s64 = curve[2].speedup;
        println!(
            "  simulated Opteron-like: S(8) = {s8:.2}, S(64) = {s64:.2} \
             (paper B=128..512 fwd: ~29.6-36.9 at 64 cores)"
        );

        // XLA/PJRT offload path, when artifacts exist and the backend is
        // compiled in (without the `xla` feature the load reports a
        // runtime error — treated as "unavailable", not a failure).
        let xla_status = if registry.available().contains(&b) {
            match XlaDwt::load(registry.dir(), b) {
                Ok(xla) => {
                    let off = So3Plan::builder(b)
                        .offload(Arc::new(xla))
                        .allow_any_bandwidth()
                        .build()?;
                    let t0 = std::time::Instant::now();
                    let c_xla = off.forward(&grid)?;
                    let dt = t0.elapsed();
                    let dev = back.max_abs_error(&c_xla);
                    println!(
                        "  xla offload: forward {} , |native - xla| = {dev:.2e}",
                        fmt_seconds(dt.as_secs_f64())
                    );
                    assert!(dev < 1e-12, "xla backend diverged from native");
                    format!("ok ({dev:.1e})")
                }
                Err(e) => {
                    // With the xla feature compiled in, a load failure is
                    // a real artifact/compile regression — propagate it.
                    if cfg!(feature = "xla") {
                        return Err(e);
                    }
                    println!("  xla offload unavailable: {e}");
                    "n/a".to_string()
                }
            }
        } else {
            println!("  xla offload: no artifacts for b={b} (run `make artifacts`)");
            "n/a".to_string()
        };

        summary.row(&[
            b.to_string(),
            fmt_seconds(inv_stats.total.as_secs_f64()),
            fmt_seconds(fwd_stats.total.as_secs_f64()),
            format!("{abs_err:.1e}"),
            format!("{rel_err:.1e}"),
            format!("{s8:.2}"),
            format!("{s64:.2}"),
            xla_status,
        ]);
        println!();
    }

    println!("=== summary ===");
    summary.print();
    println!("\nall bandwidths passed roundtrip + backend validation");
    Ok(())
}

//! Multicore scaling deep-dive: where does the speedup plateau come
//! from? Decomposes the simulated 64-core run into the paper's §5
//! effects — workload imbalance, dispatch overhead, memory contention —
//! by toggling each machine-model term.
//!
//! ```sh
//! cargo run --release --example cluster_scaling
//! ```

use so3ft::bench_util::{env_usize, Table};
use so3ft::simulator::cost::{measured_spec, TransformKind};
use so3ft::simulator::machine::{simulate_transform, MachineParams};

fn main() -> so3ft::Result<()> {
    let b = env_usize("SO3FT_B", 32);
    println!("measuring per-package costs at B={b}...\n");

    for kind in [TransformKind::Forward, TransformKind::Inverse] {
        let spec = measured_spec(b, kind)?;
        let t1 = spec.sequential_seconds();

        let ideal = MachineParams::ideal();
        let mut no_contention = MachineParams::opteron_like();
        no_contention.bw_cores = f64::INFINITY;
        let mut no_overhead = MachineParams::opteron_like();
        no_overhead.dispatch_overhead = 0.0;
        no_overhead.region_barrier = 0.0;
        let full = MachineParams::opteron_like();

        let models = [
            ("ideal machine (imbalance only)", &ideal),
            ("+ dispatch/barrier overhead", &no_contention),
            ("+ memory contention (no overhead)", &no_overhead),
            ("full Opteron-like model", &full),
        ];

        println!("--- {} (sequential {:.4}s) ---", spec.label, t1);
        let mut table = Table::new(&["model", "S(8)", "S(16)", "S(64)"]);
        for (name, params) in models {
            let s = |p: usize| t1 / simulate_transform(&spec, p, params);
            table.row(&[
                name.to_string(),
                format!("{:.2}", s(8)),
                format!("{:.2}", s(16)),
                format!("{:.2}", s(64)),
            ]);
        }
        table.print();
        println!();
    }
    println!(
        "Interpretation: imbalance alone is mild (the symmetry clusters are\n\
         small and numerous — the paper's design goal); the plateau at high\n\
         core counts is dominated by memory contention, which is exactly\n\
         the paper's §5 explanation, and is stronger for the inverse\n\
         transform because of the on-the-fly transposition."
    );
    Ok(())
}

//! Quickstart: serve transforms through `So3Service` (the front door),
//! then drop to the `So3Plan` power-user path for explicit
//! allocation-free execution.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::time::Duration;

use so3ft::service::{JobSpec, So3Service};
use so3ft::so3::coeffs::{coeff_count, So3Coeffs};
use so3ft::so3::sampling::So3Grid;
use so3ft::transform::So3Plan;

const B: usize = 32;

fn main() -> so3ft::Result<()> {
    println!(
        "bandwidth {B}: grid (2B)^3 = {} nodes, {} coefficients",
        (2 * B).pow(3),
        coeff_count(B)
    );

    // ------------------------------------------------------------------
    // The serving front door: one service, shared worker pool, plan
    // registry, pooled workspaces, micro-batching dispatcher.
    // ------------------------------------------------------------------
    let service = So3Service::builder()
        .threads(4)
        .batch_window(Duration::from_micros(200))
        .build()?;

    // The paper's workload: random coefficients, re/im uniform in [-1, 1].
    let coeffs = So3Coeffs::random(B, 2024);

    // Blocking conveniences (bandwidth comes from the payload):
    let grid = service.inverse(coeffs.clone())?; // iFSOFT
    let back = service.forward(grid)?; // FSOFT
    let abs_err = coeffs.max_abs_error(&back);
    println!("service roundtrip max abs error: {abs_err:.3e}");
    assert!(abs_err < 1e-11, "roundtrip accuracy regression");

    // The async job API: submit many jobs, wait on the handles. Same-key
    // jobs arriving within the batch window execute as one micro-batch
    // (bit-identical to per-job execution).
    let handles: Vec<_> = (0..4)
        .map(|i| service.submit(JobSpec::inverse(B), So3Coeffs::random(B, i)))
        .collect::<so3ft::Result<_>>()?;
    for h in handles {
        let out = h.wait()?;
        service.recycle(out); // buffers back to the pool: zero-alloc steady state
    }
    let stats = service.stats();
    println!(
        "service: {} jobs in {} micro-batches (max batch {}), {} cached plans, \
         {} pooled workspaces",
        stats.jobs_completed,
        stats.batches,
        stats.max_batch_size,
        stats.registry.plans,
        stats.buffers.workspaces_created,
    );

    // ------------------------------------------------------------------
    // The power-user path: explicit plan + caller-owned buffers.
    // ------------------------------------------------------------------
    let plan = So3Plan::builder(B).threads(4).build()?;
    println!("plan backend: {:?}", plan.backend());

    let mut ws = plan.make_workspace();
    let mut grid = So3Grid::zeros(B)?;
    let mut back = So3Coeffs::zeros(B);
    let inv_stats = plan.inverse_into(&coeffs, &mut grid, &mut ws)?; // iFSOFT
    let fwd_stats = plan.forward_into(&grid, &mut back, &mut ws)?; // FSOFT

    println!(
        "iFSOFT: {:?}  (dwt {:?} | transpose {:?} | fft {:?})",
        inv_stats.total, inv_stats.dwt, inv_stats.transpose, inv_stats.fft
    );
    println!(
        "FSOFT:  {:?}  (fft {:?} | transpose {:?} | dwt {:?})",
        fwd_stats.total, fwd_stats.fft, fwd_stats.transpose, fwd_stats.dwt
    );
    println!(
        "FFT stage fraction of forward: {:.1}% (paper §5 reports ~5-8% at B=512)",
        100.0 * fwd_stats.fft_fraction()
    );
    let abs_err = coeffs.max_abs_error(&back);
    println!("plan roundtrip max abs error: {abs_err:.3e}");
    assert!(abs_err < 1e-11, "roundtrip accuracy regression");

    // Batches pipeline through the same plan + workspace.
    let batch: Vec<So3Coeffs> = (0..4).map(|i| So3Coeffs::random(B, i)).collect();
    let grids = plan.inverse_batch(&batch)?;
    println!("batched {} synthesis calls through one plan", grids.len());
    println!("OK");
    Ok(())
}

//! Quickstart: build one `So3Plan`, synthesize a random band-limited
//! function on SO(3), run the forward transform allocation-free, verify
//! the roundtrip, inspect the timing breakdown.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use so3ft::pool::Schedule;
use so3ft::so3::coeffs::{coeff_count, So3Coeffs};
use so3ft::so3::sampling::So3Grid;
use so3ft::transform::So3Plan;

const B: usize = 32;

fn main() -> so3ft::Result<()> {
    println!(
        "bandwidth {B}: grid (2B)^3 = {} nodes, {} coefficients",
        (2 * B).pow(3),
        coeff_count(B)
    );

    // Plan once, like the paper's benchmark configuration: dynamic
    // scheduling, symmetry-clustered geometric partitioning, precomputed
    // Wigner tables. The plan owns every precomputed table.
    let plan = So3Plan::builder(B)
        .threads(4)
        .schedule(Schedule::Dynamic { chunk: 1 })
        .build()?;
    println!("backend: {:?}", plan.backend());

    // The paper's workload: random coefficients, re/im uniform in [-1, 1].
    let coeffs = So3Coeffs::random(B, 2024);

    // Serving path: caller-owned buffers + one reusable workspace means
    // zero grid/coefficient allocation per transform.
    let mut ws = plan.make_workspace();
    let mut grid = So3Grid::zeros(B)?;
    let mut back = So3Coeffs::zeros(B);

    let inv_stats = plan.inverse_into(&coeffs, &mut grid, &mut ws)?; // iFSOFT
    let fwd_stats = plan.forward_into(&grid, &mut back, &mut ws)?; // FSOFT

    println!(
        "iFSOFT: {:?}  (dwt {:?} | transpose {:?} | fft {:?})",
        inv_stats.total, inv_stats.dwt, inv_stats.transpose, inv_stats.fft
    );
    println!(
        "FSOFT:  {:?}  (fft {:?} | transpose {:?} | dwt {:?})",
        fwd_stats.total, fwd_stats.fft, fwd_stats.transpose, fwd_stats.dwt
    );
    println!(
        "FFT stage fraction of forward: {:.1}% (paper §5 reports ~5-8% at B=512)",
        100.0 * fwd_stats.fft_fraction()
    );

    let abs_err = coeffs.max_abs_error(&back);
    let rel_err = coeffs.max_rel_error(&back);
    println!("roundtrip max abs error: {abs_err:.3e}");
    println!("roundtrip max rel error: {rel_err:.3e}");
    assert!(abs_err < 1e-11, "roundtrip accuracy regression");

    // Batches pipeline through the same plan + workspace.
    let batch: Vec<So3Coeffs> = (0..4).map(|i| So3Coeffs::random(B, i)).collect();
    let grids = plan.inverse_batch(&batch)?;
    println!("batched {} synthesis calls through one plan", grids.len());
    println!("OK");
    Ok(())
}

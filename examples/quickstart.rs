//! Quickstart: synthesize a random band-limited function on SO(3), run
//! the forward transform, verify the roundtrip, inspect the timing
//! breakdown.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use so3ft::pool::Schedule;
use so3ft::so3::coeffs::{coeff_count, So3Coeffs};
use so3ft::transform::So3Fft;

const B: usize = 32;

fn main() -> so3ft::Result<()> {
    println!(
        "bandwidth {B}: grid (2B)^3 = {} nodes, {} coefficients",
        (2 * B).pow(3),
        coeff_count(B)
    );

    // Configure the transform like the paper's benchmark: dynamic
    // scheduling, symmetry-clustered geometric partitioning, precomputed
    // Wigner tables.
    let fft = So3Fft::builder(B)
        .threads(4)
        .schedule(Schedule::Dynamic { chunk: 1 })
        .build()?;

    // The paper's workload: random coefficients, re/im uniform in [-1, 1].
    let coeffs = So3Coeffs::random(B, 2024);

    // Synthesis (iFSOFT), then analysis (FSOFT).
    let (grid, inv_stats) = fft.inverse_with_stats(&coeffs)?;
    let (back, fwd_stats) = fft.forward_with_stats(&grid)?;

    println!(
        "iFSOFT: {:?}  (dwt {:?} | transpose {:?} | fft {:?})",
        inv_stats.total, inv_stats.dwt, inv_stats.transpose, inv_stats.fft
    );
    println!(
        "FSOFT:  {:?}  (fft {:?} | transpose {:?} | dwt {:?})",
        fwd_stats.total, fwd_stats.fft, fwd_stats.transpose, fwd_stats.dwt
    );
    println!(
        "FFT stage fraction of forward: {:.1}% (paper §5 reports ~5-8% at B=512)",
        100.0 * fwd_stats.fft_fraction()
    );

    let abs_err = coeffs.max_abs_error(&back);
    let rel_err = coeffs.max_rel_error(&back);
    println!("roundtrip max abs error: {abs_err:.3e}");
    println!("roundtrip max rel error: {rel_err:.3e}");
    assert!(abs_err < 1e-11, "roundtrip accuracy regression");
    println!("OK");
    Ok(())
}

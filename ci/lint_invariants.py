#!/usr/bin/env python3
"""In-tree invariant linter for the so3ft concurrency / unsafe surface.

Zero dependencies (stdlib only). Wired into the CI lint job; run locally
with:

    python3 ci/lint_invariants.py            # lint the tree
    python3 ci/lint_invariants.py --self-test # prove seeded violations fail

Rules (see docs/CONCURRENCY.md for the rationale):

  R1 unsafe-allowlist   `unsafe` code may appear only in the allow-listed
                        module set below. Anything else is a layering
                        violation: new unsafe belongs in an audited leaf
                        module, not sprinkled through orchestration code.
  R2 safety-comment     Every `unsafe` block / impl / fn must carry an
                        adjacent `// SAFETY:` comment (or `# Safety` doc
                        section for unsafe fns) within ADJACENCY lines
                        above it.
  R3 ordering-protocol  Every `Ordering::*` use outside tests must carry a
                        one-line protocol comment tagged `ordering:` on
                        the same line or within ADJACENCY lines above —
                        naming what the ordering synchronizes with (or
                        why Relaxed suffices).
  R4 lock-unpoisoned    Raw `.lock().unwrap()` / `.read().unwrap()` /
                        `.write().unwrap()` on sync primitives is banned
                        outside tests; use util::lock_unpoisoned /
                        read_unpoisoned / write_unpoisoned so a panicked
                        peer doesn't cascade into poisoned-lock panics.
  R5 hot-loop-hygiene   Kernel files must mark their innermost hot loops
                        with `// lint: hot-loop-begin` / `// lint:
                        hot-loop-end`; inside a marked region, timing
                        syscalls (`Instant::now`) and allocation
                        (`Vec::new`, `vec![`, `to_vec`, `Box::new`,
                        `with_capacity`, `collect()`) are banned. Each
                        file listed in HOT_FILES must contain at least
                        one marked region, so the markers cannot be
                        silently deleted to dodge the rule.

Test code is exempt from R3/R4 (but not R1/R2): the linter stops applying
those rules after a `#[cfg(test)]` module marker, inside `rust/tests/`,
and inside `rust/benches/`.
"""

import argparse
import os
import re
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "rust", "src")

# How many lines above a site we search for its justifying comment.
ADJACENCY = 6

# R1: modules allowed to contain unsafe code, relative to rust/src.
# Keep in sync with the table in docs/CONCURRENCY.md.
UNSAFE_ALLOWLIST = {
    "util.rs",  # AlignedVec (Pod casts), SyncUnsafeSlice
    "simd.rs",  # runtime ISA detection helpers
    "dwt/simd.rs",  # AVX2/FMA + NEON Wigner kernels
    "fft/simd.rs",  # AVX2/FMA + NEON butterfly kernels
    "fft/complex.rs",  # split re/im panel views over raw parts
    "fft/split_radix.rs",  # ISA dispatch into the fft/simd kernels
    "dwt/kernels.rs",  # disjoint SyncUnsafeSlice writes (matvec kernels)
    "dwt/folded.rs",  # disjoint SyncUnsafeSlice writes + ISA dispatch
    "dwt/clenshaw.rs",  # disjoint SyncUnsafeSlice writes
    "coordinator/exec.rs",  # disjoint SyncUnsafeSlice writes per (u,v) task
    "pool/runtime.rs",  # lifetime-erased JobBody handoff
    "runtime/xla_dwt.rs",  # AOT artifact mmap surface (stub)
    "transpose/mod.rs",  # in-place blocked transpose raw swaps
    "xprec.rs",  # Pod impl for DdComplex (plain f64 pairs)
}

# R5: kernel files that must contain >= 1 marked hot-loop region.
HOT_FILES = {
    "dwt/kernels.rs",
    "dwt/folded.rs",
    "dwt/simd.rs",
    "fft/radix2.rs",
    "fft/split_radix.rs",
    "fft/simd.rs",
}

HOT_BEGIN = "// lint: hot-loop-begin"
HOT_END = "// lint: hot-loop-end"

# Banned inside hot-loop regions: wall-clock reads and allocator calls.
HOT_BANNED = [
    (re.compile(r"\bInstant::now\b"), "Instant::now"),
    (re.compile(r"\bSystemTime::now\b"), "SystemTime::now"),
    (re.compile(r"\bVec::new\b"), "Vec::new"),
    (re.compile(r"\bvec!\s*\["), "vec!["),
    (re.compile(r"\.to_vec\(\)"), ".to_vec()"),
    (re.compile(r"\bBox::new\b"), "Box::new"),
    (re.compile(r"\bwith_capacity\s*\("), "with_capacity"),
    (re.compile(r"\.collect::<|\.collect\(\)"), ".collect()"),
]

RE_ORDERING = re.compile(r"\bOrdering::(Relaxed|Acquire|Release|AcqRel|SeqCst)\b")
RE_RAW_LOCK = re.compile(r"\.(lock|read|write)\(\)\s*\.unwrap\(\)")
RE_UNSAFE = re.compile(r"\bunsafe\b")
RE_CFG_TEST_MOD = re.compile(r"#\[cfg\(test\)\]")
RE_SAFETY = re.compile(r"//\s*SAFETY:", re.IGNORECASE)
RE_SAFETY_DOC = re.compile(r"///?\s*#+\s*Safety", re.IGNORECASE)
RE_ORDER_TAG = re.compile(r"//.*\bordering:", re.IGNORECASE)


class Violation:
    def __init__(self, rule, path, line, msg):
        self.rule = rule
        self.path = path
        self.line = line
        self.msg = msg

    def __str__(self):
        rel = os.path.relpath(self.path, REPO)
        return f"{rel}:{self.line}: [{self.rule}] {self.msg}"


def strip_strings(line):
    """Blank out string/char literal contents so tokens inside literals
    (e.g. an "unsafe" in an error message) don't trip the lexers."""
    line = re.sub(r'"(?:[^"\\]|\\.)*"', '""', line)
    # Char literals: only plain 'x' / '\n' forms; leave lifetimes alone.
    line = re.sub(r"'(?:[^'\\]|\\.)'", "' '", line)
    return line


def code_part(line):
    """The code before any // comment, with string contents blanked."""
    s = strip_strings(line)
    idx = s.find("//")
    return s if idx < 0 else s[:idx]


def iter_rust_files(root):
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if fn.endswith(".rs"):
                yield os.path.join(dirpath, fn)


def first_test_mod_line(lines):
    """Line index (0-based) of the first `#[cfg(test)]` marker, or
    len(lines). Everything at or after it is test code for R3/R4."""
    for i, line in enumerate(lines):
        if RE_CFG_TEST_MOD.search(line):
            return i
    return len(lines)


def has_adjacent(lines, i, pattern, extra=None):
    """True if `pattern` (or `extra`) matches on line i or above it.

    The upward scan has a budget of ADJACENCY non-comment lines;
    comment-only lines are free, so a long justifying comment block is
    always searched in full no matter how many lines it spans."""
    if pattern.search(lines[i]) or (extra is not None and extra.search(lines[i])):
        return True
    budget = ADJACENCY
    j = i - 1
    while j >= 0 and budget > 0:
        if pattern.search(lines[j]):
            return True
        if extra is not None and extra.search(lines[j]):
            return True
        if not lines[j].strip().startswith("//"):
            budget -= 1
        j -= 1
    return False


def lint_file(path, violations):
    rel = os.path.relpath(path, SRC).replace(os.sep, "/")
    in_tests_dir = "rust/tests/" in path.replace(os.sep, "/") or "rust/benches/" in path.replace(
        os.sep, "/"
    )
    with open(path, encoding="utf-8") as f:
        lines = f.read().split("\n")

    test_start = 0 if in_tests_dir else first_test_mod_line(lines)

    hot_depth = 0
    hot_regions = 0

    for i, raw in enumerate(lines):
        lineno = i + 1
        code = code_part(raw)
        in_test = in_tests_dir or i >= test_start

        # R5 region tracking (comments, so inspect the raw line).
        if HOT_BEGIN in raw:
            hot_depth += 1
            hot_regions += 1
            continue
        if HOT_END in raw:
            if hot_depth == 0:
                violations.append(
                    Violation("hot-loop-hygiene", path, lineno, "hot-loop-end without begin")
                )
            else:
                hot_depth -= 1
            continue
        if hot_depth > 0:
            for pat, name in HOT_BANNED:
                if pat.search(code):
                    violations.append(
                        Violation(
                            "hot-loop-hygiene",
                            path,
                            lineno,
                            f"`{name}` inside a marked hot loop "
                            "(timing/allocation belongs outside the kernel)",
                        )
                    )

        # R1 + R2: unsafe surface (applies to test code too — unsafe in a
        # test needs the same audit trail).
        if RE_UNSAFE.search(code):
            if not in_tests_dir and rel not in UNSAFE_ALLOWLIST:
                violations.append(
                    Violation(
                        "unsafe-allowlist",
                        path,
                        lineno,
                        f"`unsafe` outside the allow-listed module set ({rel}); "
                        "extend UNSAFE_ALLOWLIST deliberately or move the code",
                    )
                )
            if not has_adjacent(lines, i, RE_SAFETY, RE_SAFETY_DOC):
                violations.append(
                    Violation(
                        "safety-comment",
                        path,
                        lineno,
                        "`unsafe` without an adjacent `// SAFETY:` comment "
                        f"(within {ADJACENCY} lines above)",
                    )
                )

        if in_test:
            continue

        # R3: every Ordering::* use carries an `ordering:` protocol tag.
        if RE_ORDERING.search(code):
            # `use std::sync::atomic::Ordering` imports don't count; the
            # regex above only matches qualified `Ordering::Variant` uses,
            # so plain imports never get here.
            if not has_adjacent(lines, i, RE_ORDER_TAG):
                violations.append(
                    Violation(
                        "ordering-protocol",
                        path,
                        lineno,
                        "`Ordering::*` without an `// ordering:` protocol comment "
                        "(same line or above) naming what it synchronizes with",
                    )
                )

        # R4: raw lock unwraps outside util.rs (which defines the
        # helpers) are banned in non-test code.
        if rel != "util.rs" and RE_RAW_LOCK.search(code):
            violations.append(
                Violation(
                    "lock-unpoisoned",
                    path,
                    lineno,
                    "raw `.lock()/.read()/.write().unwrap()`; use "
                    "util::{lock,read,write}_unpoisoned so peer panics "
                    "don't cascade into poisoned-lock panics",
                )
            )

    if hot_depth != 0:
        violations.append(
            Violation("hot-loop-hygiene", path, len(lines), "unclosed hot-loop-begin region")
        )
    if rel in HOT_FILES and hot_regions == 0:
        violations.append(
            Violation(
                "hot-loop-hygiene",
                path,
                1,
                "kernel file has no `// lint: hot-loop-begin` region; "
                "mark the innermost loop (see docs/CONCURRENCY.md)",
            )
        )


def lint_tree(src=SRC):
    violations = []
    for path in iter_rust_files(src):
        lint_file(path, violations)
    return violations


# --------------------------------------------------------------------------
# Self-test: each rule class must fail on a seeded violation and pass on
# the corrected form. Run in CI before linting the tree so a silently
# broken linter can't green-light the tree.
# --------------------------------------------------------------------------

SELF_TEST_CASES = [
    (
        "unsafe-allowlist",
        # Seeded: unsafe in a module not on the allowlist.
        "disallowed.rs",
        """
pub fn f(p: *const u8) -> u8 {
    // SAFETY: caller guarantees p is valid.
    unsafe { *p }
}
""",
        None,  # no clean variant: the module itself is the violation
    ),
    (
        "safety-comment",
        "util.rs",
        """
pub fn f(p: *const u8) -> u8 {
    unsafe { *p }
}
""",
        """
pub fn f(p: *const u8) -> u8 {
    // SAFETY: caller guarantees p is valid for reads.
    unsafe { *p }
}
""",
    ),
    (
        "ordering-protocol",
        "counters.rs",
        """
use std::sync::atomic::{AtomicU64, Ordering};
pub fn bump(c: &AtomicU64) {
    c.fetch_add(1, Ordering::Relaxed);
}
""",
        """
use std::sync::atomic::{AtomicU64, Ordering};
pub fn bump(c: &AtomicU64) {
    // ordering: Relaxed — standalone statistic, no data published.
    c.fetch_add(1, Ordering::Relaxed);
}
""",
    ),
    (
        "lock-unpoisoned",
        "locks.rs",
        """
use std::sync::Mutex;
pub fn peek(m: &Mutex<u64>) -> u64 {
    *m.lock().unwrap()
}
""",
        """
use std::sync::Mutex;
use crate::util::lock_unpoisoned;
pub fn peek(m: &Mutex<u64>) -> u64 {
    *lock_unpoisoned(m)
}
""",
    ),
    (
        "hot-loop-hygiene",
        "dwt/kernels.rs",
        """
pub fn kernel(x: &mut [f64]) {
    // lint: hot-loop-begin
    for v in x.iter_mut() {
        let t = std::time::Instant::now();
        *v += t.elapsed().as_secs_f64();
    }
    // lint: hot-loop-end
}
""",
        """
pub fn kernel(x: &mut [f64]) {
    // lint: hot-loop-begin
    for v in x.iter_mut() {
        *v += 1.0;
    }
    // lint: hot-loop-end
}
""",
    ),
]


def self_test():
    failures = []
    for rule, relname, bad, good in SELF_TEST_CASES:
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, relname)
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with open(path, "w", encoding="utf-8") as f:
                f.write(bad)
            vs = []
            # Lint relative to tmp as the source root so allowlist paths
            # resolve the same way they do for the real tree.
            global SRC
            saved = SRC
            SRC = tmp
            try:
                lint_file(path, vs)
            finally:
                SRC = saved
            if not any(v.rule == rule for v in vs):
                failures.append(f"seeded `{rule}` violation was NOT caught")
            if good is not None:
                with open(path, "w", encoding="utf-8") as f:
                    f.write(good)
                vs = []
                SRC = tmp
                try:
                    lint_file(path, vs)
                finally:
                    SRC = saved
                wrong = [v for v in vs if v.rule == rule]
                if wrong:
                    failures.append(
                        f"clean `{rule}` variant still flagged: "
                        + "; ".join(str(v) for v in wrong)
                    )
    if failures:
        for f in failures:
            print(f"SELF-TEST FAIL: {f}", file=sys.stderr)
        return 1
    print(f"self-test ok: {len(SELF_TEST_CASES)} rule classes fail on seeded violations")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--self-test", action="store_true", help="run the seeded-violation self-test")
    ap.add_argument("--src", default=SRC, help="source root to lint (default rust/src)")
    args = ap.parse_args()

    if args.self_test:
        sys.exit(self_test())

    violations = lint_tree(args.src)
    if violations:
        for v in violations:
            print(v, file=sys.stderr)
        print(f"\n{len(violations)} invariant violation(s)", file=sys.stderr)
        sys.exit(1)
    print("lint_invariants: tree clean")
    sys.exit(0)


if __name__ == "__main__":
    main()

#!/usr/bin/env python3
"""bench-smoke regression gate.

Compares the per-stage wall times in a freshly generated BENCH_fft.json
(written by `cargo run --release --example e2e_benchmark`) against the
checked-in ci/bench_baseline.json. A stage regresses when its observed
time exceeds `baseline * threshold` (threshold lives in the baseline's
meta; deliberately generous — this is a smoke-level net against
order-of-magnitude regressions, not a microbenchmark). Byte-counting
stages (`*_bytes`, e.g. the large-B sweep's ledger peak) instead use a
fixed tight BYTES_HEADROOM: memory footprints are deterministic, so the
gate pins them closely. FLOOR_STAGES invert the polarity — observed
must be >= the baseline (the chaos gate's typed-rejection count).

Usage:
  check_bench.py BENCH_fft.json ci/bench_baseline.json [options]

Options:
  --summary PATH   also write the delta table as GitHub-flavored
                   markdown to PATH (e.g. "$GITHUB_STEP_SUMMARY"); used
                   by CI so a failing gate shows the table in the job
                   summary instead of a bare exit code.
  --update         regenerate the baseline: rewrite every stage value of
                   every existing baseline key from the observed bench
                   output (keys, threshold, and note are preserved), then
                   exit 0.  Run against a downloaded BENCH_fft artifact
                   to tighten the baseline after a hardware/engine
                   change.
  --headroom K     with --update, write observed*K instead of the raw
                   observation (default 3.0), floored at 5 ms — the gate
                   is a smoke net, and sub-ms timings on shared runners
                   jitter far beyond the 2x threshold; a raw-observation
                   baseline would turn it into a flaky tight pin.

Exit codes: 0 ok, 1 regression/missing data, 2 usage.
"""

import json
import sys

# Gated stage keys. All are "lower is better": transform wall times
# from e2e_benchmark, the serve-bench service records (p99_s =
# per-bandwidth job latency tail, per_job_s = mixed-traffic wall seconds
# per job — the inverse of throughput, so a throughput regression raises
# it past the ceiling), the plan_build wisdom records (overhead_s =
# store-cached Measure build minus Estimate build — a cache hit must
# stay within a small constant of Estimate), and the large-B sweep's
# ledger peak memory (peak_bytes — streamed execution must stay below
# the full-materialization footprint, see large_b_peak_bytes).
STAGES = (
    "fft_s",
    "transpose_s",
    "dwt_s",
    "total_s",
    "p99_s",
    "per_job_s",
    "overhead_s",
    "peak_bytes",
)

# Floor-gated stage keys: "higher (or equal) is better". Used by the
# chaos-smoke job's saturation probe — `rejected_jobs` counts typed
# Overloaded rejections from the serve-bench rate ramp, and the gate
# fails if the service stopped shedding load (observed < baseline
# floor). Floor stages are hand-set in the baseline and are never
# rewritten by --update.
FLOOR_STAGES = ("rejected_jobs",)

# Byte-counting stages bypass the baseline meta's wall-time threshold:
# ledger footprints are deterministic (no shared-runner jitter), so a
# tight fixed 10% covers allocator/layout drift without letting a 2x
# memory blow-up pass the gate.
BYTES_HEADROOM = 1.1


def is_bytes(stage):
    return stage.endswith("_bytes")


def fmt_val(stage, v):
    """One stage value for the delta tables (MiB for byte stages,
    bare integers for floor-gated counts)."""
    if is_bytes(stage):
        return f"{v / (1 << 20):8.1f}Mi"
    if stage in FLOOR_STAGES:
        return f"{v:10.0f}"
    return f"{v:9.6f}s"


def key(record):
    return (
        record.get("kind"),
        record.get("b"),
        record.get("threads"),
        record.get("engine"),
    )


def fmt_key(k):
    return f"{k[0]} b={k[1]} t={k[2]} [{k[3]}]"


# Never write a ceiling below this: sub-ms stage timings on shared CI
# runners jitter far beyond the gate's 2x threshold.
UPDATE_FLOOR_S = 0.005


def update_baseline(bench, base, base_path, headroom):
    observed_by_key = {key(r): r for r in bench.get("records", [])}
    updated = 0
    missing = []
    for want in base.get("baseline", []):
        got = observed_by_key.get(key(want))
        if got is None:
            missing.append(fmt_key(key(want)))
            continue
        for stage in STAGES:
            if stage in want and stage in got:
                if is_bytes(stage):
                    # Deterministic footprints: fixed tight headroom, no
                    # sub-ms jitter floor.
                    want[stage] = int(float(got[stage]) * BYTES_HEADROOM)
                else:
                    want[stage] = round(
                        max(float(got[stage]) * headroom, UPDATE_FLOOR_S), 6
                    )
                updated += 1
    with open(base_path, "w") as f:
        json.dump(base, f, indent=2)
        f.write("\n")
    print(
        f"baseline updated: {updated} stage values rewritten into {base_path} "
        f"(observed x {headroom} headroom, {UPDATE_FLOOR_S}s floor)"
    )
    for k in missing:
        print(f"  WARNING: no observed record for baseline key {k} (left unchanged)")
    return 0


def main(argv):
    summary_path = None
    update = False
    headroom = 3.0
    it = iter(argv[1:])
    positional = []
    for a in it:
        if a == "--summary":
            summary_path = next(it, None)
            if summary_path is None:
                print(__doc__, file=sys.stderr)
                return 2
        elif a == "--update":
            update = True
        elif a == "--headroom":
            raw = next(it, None)
            try:
                headroom = float(raw)
            except (TypeError, ValueError):
                print(f"--headroom needs a number, got {raw!r}", file=sys.stderr)
                return 2
            if headroom < 1.0:
                print("--headroom must be >= 1.0", file=sys.stderr)
                return 2
        elif a.startswith("--"):
            print(f"unknown flag {a}\n{__doc__}", file=sys.stderr)
            return 2
        else:
            positional.append(a)
    if len(positional) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    with open(positional[0]) as f:
        bench = json.load(f)
    with open(positional[1]) as f:
        base = json.load(f)

    if update:
        return update_baseline(bench, base, positional[1], headroom)
    if headroom != 3.0:
        print(
            "WARNING: --headroom only affects --update; the gate threshold "
            "comes from the baseline's meta",
            file=sys.stderr,
        )

    threshold = float(base.get("meta", {}).get("threshold", 2.0))
    observed_by_key = {key(r): r for r in bench.get("records", [])}
    failures = []
    checked = 0
    # (key, stage, baseline, observed, ratio, status) rows of the delta
    # table — printed to stdout and optionally to the markdown summary.
    rows = []

    for want in base.get("baseline", []):
        k = key(want)
        got = observed_by_key.get(k)
        if got is None:
            failures.append(f"{fmt_key(k)}: record missing from {positional[0]}")
            continue
        for stage in STAGES:
            if stage not in want:
                continue
            stage_threshold = BYTES_HEADROOM if is_bytes(stage) else threshold
            allowed = want[stage] * stage_threshold
            observed = got.get(stage)
            if observed is None:
                failures.append(f"{fmt_key(k)}: stage {stage} missing from bench output")
                continue
            checked += 1
            ratio = observed / want[stage] if want[stage] > 0 else float("inf")
            status = "ok" if observed <= allowed else "REGRESSION"
            rows.append((k, stage, want[stage], observed, ratio, status))
            if observed > allowed:
                failures.append(
                    f"{fmt_key(k)} {stage}: {fmt_val(stage, observed).strip()} > "
                    f"{fmt_val(stage, allowed).strip()} (baseline "
                    f"{fmt_val(stage, want[stage]).strip()} x {stage_threshold})"
                )
        for stage in FLOOR_STAGES:
            if stage not in want:
                continue
            observed = got.get(stage)
            if observed is None:
                failures.append(f"{fmt_key(k)}: stage {stage} missing from bench output")
                continue
            checked += 1
            ratio = observed / want[stage] if want[stage] > 0 else float("inf")
            status = "ok" if observed >= want[stage] else "REGRESSION"
            rows.append((k, stage, want[stage], observed, ratio, status))
            if observed < want[stage]:
                failures.append(
                    f"{fmt_key(k)} {stage}: {fmt_val(stage, observed).strip()} < "
                    f"floor {fmt_val(stage, want[stage]).strip()} "
                    f"(floor stage: higher is better)"
                )

    # Per-stage delta table (vs baseline, not vs the threshold ceiling).
    header = f"{'record':44s} {'stage':12s} {'baseline':>10s} {'observed':>10s} {'delta':>8s} status"
    print(header)
    print("-" * len(header))
    for k, stage, want_v, got_v, ratio, status in rows:
        print(
            f"{fmt_key(k):44s} {stage:12s} {fmt_val(stage, want_v)} "
            f"{fmt_val(stage, got_v)} {ratio:7.2f}x {status}"
        )

    if checked == 0:
        failures.append("no stage timings checked — baseline empty or keys mismatched")

    verdict_ok = not failures
    if summary_path:
        try:
            with open(summary_path, "a") as f:
                f.write("## bench-smoke gate: " + ("passed" if verdict_ok else "FAILED") + "\n\n")
                f.write(
                    f"threshold: observed ≤ baseline × {threshold} "
                    f"(byte stages × {BYTES_HEADROOM})\n\n"
                )
                f.write("| record | stage | baseline | observed | delta | status |\n")
                f.write("|---|---|---:|---:|---:|---|\n")
                for k, stage, want_v, got_v, ratio, status in rows:
                    mark = "✅" if status == "ok" else "❌"
                    f.write(
                        f"| `{fmt_key(k)}` | {stage} | {fmt_val(stage, want_v).strip()} "
                        f"| {fmt_val(stage, got_v).strip()} "
                        f"| {ratio:.2f}x | {mark} {status} |\n"
                    )
                if failures:
                    f.write("\n**Failures:**\n\n")
                    for x in failures:
                        f.write(f"- {x}\n")
                f.write("\n")
        except OSError as e:
            print(f"WARNING: could not write summary {summary_path}: {e}", file=sys.stderr)

    if failures:
        print("\nbench-smoke regression gate FAILED:")
        for x in failures:
            print(f"  - {x}")
        return 1
    print(f"\nbench-smoke gate passed: {checked} stage timings within {threshold}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

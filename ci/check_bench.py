#!/usr/bin/env python3
"""bench-smoke regression gate.

Compares the per-stage wall times in a freshly generated BENCH_fft.json
(written by `cargo run --release --example e2e_benchmark`) against the
checked-in ci/bench_baseline.json. A stage regresses when its observed
time exceeds `baseline * threshold` (threshold lives in the baseline's
meta; deliberately generous — this is a smoke-level net against
order-of-magnitude regressions, not a microbenchmark).

Usage: check_bench.py BENCH_fft.json ci/bench_baseline.json
Exit codes: 0 ok, 1 regression/missing data, 2 usage.
"""

import json
import sys

STAGES = ("fft_s", "transpose_s", "dwt_s", "total_s")


def key(record):
    return (
        record.get("kind"),
        record.get("b"),
        record.get("threads"),
        record.get("engine"),
    )


def main(argv):
    if len(argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    with open(argv[1]) as f:
        bench = json.load(f)
    with open(argv[2]) as f:
        base = json.load(f)

    threshold = float(base.get("meta", {}).get("threshold", 2.0))
    observed_by_key = {key(r): r for r in bench.get("records", [])}
    failures = []
    checked = 0

    for want in base.get("baseline", []):
        k = key(want)
        got = observed_by_key.get(k)
        if got is None:
            failures.append(f"{k}: record missing from {argv[1]}")
            continue
        for stage in STAGES:
            if stage not in want:
                continue
            allowed = want[stage] * threshold
            observed = got.get(stage)
            if observed is None:
                failures.append(f"{k}: stage {stage} missing from bench output")
                continue
            checked += 1
            status = "ok" if observed <= allowed else "REGRESSION"
            print(
                f"{k[0]} b={k[1]} threads={k[2]} {stage}: "
                f"observed {observed:.6f}s, allowed {allowed:.6f}s [{status}]"
            )
            if observed > allowed:
                failures.append(
                    f"{k} {stage}: {observed:.6f}s > {allowed:.6f}s "
                    f"(baseline {want[stage]:.6f}s x {threshold})"
                )

    if checked == 0:
        failures.append("no stage timings checked — baseline empty or keys mismatched")

    if failures:
        print("\nbench-smoke regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\nbench-smoke gate passed: {checked} stage timings within {threshold}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
